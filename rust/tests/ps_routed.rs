//! The routed-fleet suite: N-server sharded PS topology pinned against
//! the single-server semantics it must reproduce exactly.
//!
//! * A seeded property test drives identical randomized op sequences
//!   through a `RoutedTransport` fanned over N in-process servers and
//!   through one unsplit server, and every pull must come back
//!   observationally identical (contract 11 at the transport level).
//! * Run-level parity: staleness-0 Lasso and MF runs are bitwise
//!   identical in-process, over one TCP server, and over a two-server
//!   routed fleet — on *both* orderings of the server list.
//! * Chaos: killing one of two servers mid-run and restarting it from
//!   its checkpoint completes every round, lands within tolerance, and
//!   meters reconnects on exactly the killed server's link.
//! * Fault injection composes with routing: a seeded fault plan over a
//!   two-server run stays bitwise invisible under retry.
//! * `strads ps-stats` output labels each fleet member with its shard
//!   range and route position.

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::ps::transport::tcp::TcpTransport;
use strads::ps::transport::{InProcTransport, RouteMap, RoutedTransport, Transport};
use strads::ps::{
    CheckpointConfig, ParameterServer, PsTcpServer, PullSpec, StalenessPolicy, TransportKind,
};
use strads::util::Rng;
use strads::workers::{run_distributed, DistributedReport};

// ---------------------------------------------------------------------
// Split/merge property test: routed N-server fleet vs one unsplit
// server, identical op sequences, observationally identical pulls.
// ---------------------------------------------------------------------

const KEY_SPACE: usize = 160;
/// Reads and writes also probe past the dense key space (hashed keys).
const MODEL_SPACE: usize = KEY_SPACE + 20;

fn in_seg(segs: &[(usize, usize)], key: usize) -> bool {
    segs.iter().any(|&(s, l)| key >= s && key < s + l)
}

/// Build a routed transport over `servers` in-process servers, each
/// hosting its `RouteMap` share, plus the unsplit reference server.
fn routed_and_reference(
    segs: &[(usize, usize)],
    servers: usize,
) -> (RoutedTransport, InProcTransport) {
    let route = Arc::new(RouteMap::new(segs, servers));
    let inner: Vec<Box<dyn Transport>> = (0..servers)
        .map(|i| {
            let host = Arc::new(ParameterServer::with_segments(
                2,
                1,
                StalenessPolicy::Bounded(0),
                &route.server_segments(i),
            ));
            Box::new(InProcTransport::new(host, 0)) as Box<dyn Transport>
        })
        .collect();
    let routed = RoutedTransport::new(inner, route, Arc::new(AtomicU64::new(0)));
    let single = Arc::new(ParameterServer::with_segments(
        2,
        1,
        StalenessPolicy::Bounded(0),
        segs,
    ));
    (routed, InProcTransport::new(single, 0))
}

/// Pull the same spec through both transports and compare what a
/// client can observe. Values are compared bitwise; range *versions*
/// are exempt by design — a sub-segment is its own epoch chunk, so a
/// partial publish moves fewer chunk versions on the fleet than on the
/// unsplit store (the min-fold is still a valid oldest-across-the-span
/// bound, pinned per-shape by the unit tests in `routed.rs`).
fn compare_pull(
    routed: &mut RoutedTransport,
    single: &mut InProcTransport,
    segs: &[(usize, usize)],
    spec: &PullSpec,
    ctx: &str,
) {
    let a = routed.pull(spec, 0).unwrap();
    let b = single.pull(spec, 0).unwrap();
    assert_eq!(a.ranges.len(), b.ranges.len(), "{ctx}");
    for (ra, rb) in a.ranges.iter().zip(&b.ranges) {
        assert_eq!(ra.start(), rb.start(), "{ctx}");
        let bits_a: Vec<u32> = ra.values().iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = rb.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "{ctx}: range at {} diverged", ra.start());
    }
    for ((ca, cb), &key) in a.cells.iter().zip(&b.cells).zip(&spec.keys) {
        assert_eq!(
            ca.value.to_bits(),
            cb.value.to_bits(),
            "{ctx}: cell {key} diverged: {} vs {}",
            ca.value,
            cb.value
        );
        if !in_seg(segs, key) {
            // hashed cells carry per-cell versions — those must agree
            assert_eq!(ca.version, cb.version, "{ctx}: hashed cell {key} version");
        }
    }
    assert_eq!(a.gap, b.gap, "{ctx}: staleness gap diverged");
    assert_eq!(a.waited, b.waited, "{ctx}");
}

fn run_split_equivalence(seed: u64, segs: &[(usize, usize)], servers: usize) {
    let (mut routed, mut single) = routed_and_reference(segs, servers);
    let mut rng = Rng::new(seed);
    let mut last_flush: Option<(Vec<(usize, f64)>, u64, u64)> = None;
    for step in 0..300u64 {
        let ctx = format!("seed {seed}, {servers} servers, step {step}");
        match rng.below(6) {
            0 => {
                let n = rng.below(24) + 1;
                let entries: Vec<(usize, f64)> = (0..n)
                    .map(|_| (rng.below(MODEL_SPACE), rng.f64() * 2.0 - 1.0))
                    .collect();
                let version = rng.below(64) as u64;
                routed.publish(&entries, version).unwrap();
                single.publish(&entries, version).unwrap();
            }
            1 => {
                let start = rng.below(MODEL_SPACE - 1);
                let len = rng.below(MODEL_SPACE - start) + 1;
                let values: Vec<f64> = (0..len).map(|_| rng.f64()).collect();
                let version = rng.below(64) as u64;
                routed.publish_range(start, &values, version).unwrap();
                single.publish_range(start, &values, version).unwrap();
            }
            2 => {
                let start = rng.below(MODEL_SPACE - 1);
                let len = rng.below(MODEL_SPACE - start) + 1;
                let values: Vec<f32> = (0..len).map(|_| rng.f64() as f32).collect();
                let version = rng.below(64) as u64;
                routed.publish_range_f32(start, &values, version).unwrap();
                single.publish_range_f32(start, &values, version).unwrap();
            }
            3 => {
                // flush: fresh (round, block), or a replay of the last
                // one — the dedup ledgers must agree either way
                if rng.below(4) == 0 {
                    if let Some((deltas, round, block)) = &last_flush {
                        let a = routed.flush(deltas, *round, *block).unwrap();
                        let b = single.flush(deltas, *round, *block).unwrap();
                        assert!(!a && !b, "{ctx}: replayed flush must be dropped by both");
                        continue;
                    }
                }
                let n = rng.below(16) + 1;
                let deltas: Vec<(usize, f64)> = (0..n)
                    .map(|_| (rng.below(MODEL_SPACE), rng.f64() - 0.5))
                    .collect();
                let block = rng.below(8) as u64;
                let a = routed.flush(&deltas, step, block).unwrap();
                let b = single.flush(&deltas, step, block).unwrap();
                assert_eq!(a, b, "{ctx}: flush verdicts diverged");
                last_flush = Some((deltas, step, block));
            }
            4 => {
                routed.advance_applied(step).unwrap();
                single.advance_applied(step).unwrap();
            }
            _ => {
                let mut spec = PullSpec::default();
                for _ in 0..rng.below(3) {
                    let start = rng.below(MODEL_SPACE - 1);
                    let len = rng.below((MODEL_SPACE - start).min(40)) + 1;
                    spec.push_range(start, len);
                }
                for _ in 0..rng.below(5) {
                    spec.push_key(rng.below(MODEL_SPACE));
                }
                compare_pull(&mut routed, &mut single, segs, &spec, &ctx);
            }
        }
    }
    // Final sweep: the whole space as one range plus every key.
    let spec = PullSpec {
        ranges: vec![(0, MODEL_SPACE)],
        keys: (0..MODEL_SPACE).collect(),
    };
    compare_pull(&mut routed, &mut single, segs, &spec, &format!("seed {seed} final sweep"));
}

#[test]
fn random_split_merge_matches_the_unsplit_server() {
    for seed in [1u64, 7, 42] {
        for servers in [2usize, 3, 5] {
            // segments covering parts of the key space (mixed routing)
            run_split_equivalence(seed, &[(3, 50), (70, 40)], servers);
            // one segment covering everything touched
            run_split_equivalence(seed ^ 0xfeed, &[(0, MODEL_SPACE)], servers);
            // no segments: hashed-only routing
            run_split_equivalence(seed ^ 0xbeef, &[], servers);
        }
    }
}

// ---------------------------------------------------------------------
// Run-level bitwise parity: in-process ≡ one server ≡ two servers.
// ---------------------------------------------------------------------

fn loopback_host() -> (PsTcpServer, String) {
    let host = PsTcpServer::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = host.local_addr().to_string();
    (host, addr)
}

fn base_cfg(workers: usize) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = 2;
    cfg
}

fn tcp_cfg(workers: usize, addr: &str) -> RunConfig {
    let mut cfg = base_cfg(workers);
    cfg.ps.transport = TransportKind::Tcp;
    cfg.ps.addr = addr.to_string();
    cfg
}

fn run_lasso(cfg: &RunConfig, rounds: usize, seed: u64) -> (DistributedReport, Vec<f64>) {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), seed);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = run_distributed(&mut problem, cfg, rounds, "tiny").unwrap();
    (report, problem.beta().to_vec())
}

fn obj_bits(report: &DistributedReport) -> Vec<u64> {
    report.trace.points.iter().map(|p| p.objective.to_bits()).collect()
}

fn assert_beta_eq(a: &[f64], b: &[f64], what: &str) {
    for (j, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: beta[{j}] diverged: {x} vs {y}");
    }
}

#[test]
fn lasso_staleness0_is_bitwise_identical_across_fleet_sizes() {
    // Contract 11: the same staleness-0 run in-process, over one TCP
    // server, and over a routed two-server fleet — on both orderings
    // of the server list — produces bit-for-bit the same objective
    // trajectory and final model.
    let rounds = 120;
    let (inproc, inproc_beta) = run_lasso(&base_cfg(4), rounds, 42);
    assert_eq!(inproc.route_servers, 1);

    let (host, addr) = loopback_host();
    let (one, one_beta) = run_lasso(&tcp_cfg(4, &addr), rounds, 42);
    host.stop();
    assert_eq!(one.route_servers, 1);
    assert_eq!(obj_bits(&inproc), obj_bits(&one), "inproc vs one-server tcp");
    assert_beta_eq(&inproc_beta, &one_beta, "inproc vs one-server tcp");

    for flipped in [false, true] {
        let (h1, a1) = loopback_host();
        let (h2, a2) = loopback_host();
        let list = if flipped { format!("{a2},{a1}") } else { format!("{a1},{a2}") };
        let (two, two_beta) = run_lasso(&tcp_cfg(4, &list), rounds, 42);
        h1.stop();
        h2.stop();
        assert_eq!(two.route_servers, 2);
        assert_eq!(two.rounds, rounds);
        assert!(two.route_fanout_rpcs > 0, "the fan-out meter must tick");
        assert_eq!(two.socket_bytes_per_server.len(), 2);
        assert!(
            two.socket_bytes_per_server.iter().all(|&b| b > 0),
            "both servers must carry real traffic: {:?}",
            two.socket_bytes_per_server
        );
        assert_eq!(
            obj_bits(&inproc),
            obj_bits(&two),
            "two-server trajectory diverged (flipped={flipped})"
        );
        assert_beta_eq(&inproc_beta, &two_beta, "two-server beta");
    }
}

#[test]
fn mf_staleness0_is_bitwise_identical_at_two_servers() {
    // Same pin for CCD++ MF: the f32 factor slabs split across two
    // servers and come back bit-exact, both server orderings.
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let run = |cfg: &RunConfig| {
        let mut problem = DistMf::new(&data.a, 4, 0.05, 32);
        let rounds = problem.rounds_for_iters(3);
        run_distributed(&mut problem, cfg, rounds, "tiny").unwrap()
    };

    let inproc = run(&RunConfig { workers: 4, ..Default::default() });

    for flipped in [false, true] {
        let (h1, a1) = loopback_host();
        let (h2, a2) = loopback_host();
        let list = if flipped { format!("{a2},{a1}") } else { format!("{a1},{a2}") };
        let mut cfg = RunConfig { workers: 4, ..Default::default() };
        cfg.ps.transport = TransportKind::Tcp;
        cfg.ps.addr = list;
        let two = run(&cfg);
        h1.stop();
        h2.stop();
        assert_eq!(two.route_servers, 2);
        assert_eq!(
            obj_bits(&inproc),
            obj_bits(&two),
            "MF two-server trajectory diverged (flipped={flipped}): {} vs {}",
            inproc.trace.final_objective(),
            two.trace.final_objective()
        );
    }
}

// ---------------------------------------------------------------------
// Chaos: kill one of two servers mid-run, restart from its checkpoint.
// ---------------------------------------------------------------------

#[test]
fn killing_one_of_two_servers_mid_run_recovers_from_its_checkpoint() {
    // Per-server checkpoints compose with routing: each fleet member
    // dumps only the shards it owns, so restarting the killed member
    // from its own checkpoint restores exactly its slice. The retrying
    // workers ride out the crash on that one link — the run completes
    // every round, lands within tolerance of the undisturbed fleet,
    // and reconnects are metered on exactly the killed server's link.
    let rounds = 1500;
    let (h1, a1) = loopback_host();
    let (h2, a2) = loopback_host();
    let (baseline, _) = run_lasso(&tcp_cfg(3, &format!("{a1},{a2}")), rounds, 17);
    h1.stop();
    h2.stop();

    let dir = std::env::temp_dir().join(format!("strads_routed_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let ckpt = CheckpointConfig { dir: dir.clone(), every: 2, keep: 2 };
    let (survivor, a1) = loopback_host();
    let victim = PsTcpServer::bind_with("127.0.0.1:0", Some(ckpt.clone())).unwrap();
    let a2 = victim.local_addr().to_string();
    let mut cfg = tcp_cfg(3, &format!("{a1},{a2}"));
    cfg.ps.retry_max = 40;
    cfg.ps.retry_backoff_ms = 10;
    let runner = std::thread::spawn(move || run_lasso(&cfg, rounds, 17));

    // Wait for the victim's first checkpoint (proof the run is
    // underway), let it advance a little further, then pull the rug.
    let ckpt_file = dir.join("ps.ckpt");
    let begin = std::time::Instant::now();
    while !ckpt_file.exists() {
        assert!(
            begin.elapsed() < std::time::Duration::from_secs(30),
            "the victim never produced a checkpoint"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    std::thread::sleep(std::time::Duration::from_millis(100));
    victim.stop();
    let restarted = PsTcpServer::bind_with(&a2, Some(ckpt)).expect("rebind the crashed address");

    let (report, _) = runner.join().expect("the interrupted run must not panic");
    survivor.stop();
    restarted.stop();
    let _ = std::fs::remove_dir_all(&dir);

    assert_eq!(report.rounds, rounds, "the interrupted run must complete every round");
    assert_eq!(report.route_servers, 2);
    assert!(report.reconnects > 0, "the crash must have forced reconnects");
    assert_eq!(report.reconnects_per_server.len(), 2);
    assert!(
        report.reconnects_per_server[1] > 0,
        "the killed server's link must have reconnected: {:?}",
        report.reconnects_per_server
    );
    assert_eq!(
        report.reconnects_per_server[0], 0,
        "the surviving server's link must not have reconnected: {:?}",
        report.reconnects_per_server
    );
    let base = baseline.trace.final_objective();
    let got = report.trace.final_objective();
    assert!(
        ((got - base) / base).abs() < 0.05,
        "restored fleet must land near the undisturbed objective: {got} vs {base}"
    );
    let first = report.trace.points.first().unwrap().objective;
    assert!(got < first, "no progress across the restart: {first} -> {got}");
}

#[test]
fn routed_fault_injection_stays_bitwise_invisible() {
    // The PR-7 invisibility pin composed with routing: a seeded fault
    // schedule over both links of a two-server run changes nothing —
    // retry replays are idempotent per server, and the routed clocks
    // stay in lock-step through the churn.
    let rounds = 120;
    let (h1, a1) = loopback_host();
    let (h2, a2) = loopback_host();
    let (clean, clean_beta) = run_lasso(&tcp_cfg(4, &format!("{a1},{a2}")), rounds, 42);
    h1.stop();
    h2.stop();
    assert_eq!(clean.reconnects, 0, "the clean run must not retry anything");

    let (h1, a1) = loopback_host();
    let (h2, a2) = loopback_host();
    let mut cfg = tcp_cfg(4, &format!("{a1},{a2}"));
    cfg.ps.retry_max = 6;
    cfg.ps.retry_backoff_ms = 1;
    cfg.ps.fault_plan =
        "seed=11,drop=0.05,err=0.03,delay=0.04,delay_ms=1,ops=pull|flush".to_string();
    let (faulted, faulted_beta) = run_lasso(&cfg, rounds, 42);
    h1.stop();
    h2.stop();

    assert!(faulted.reconnects > 0, "the fault plan must have forced reconnects");
    assert_eq!(faulted.route_servers, 2);
    assert_eq!(
        obj_bits(&clean),
        obj_bits(&faulted),
        "fault-injected two-server trajectory must be bitwise identical"
    );
    assert_beta_eq(&clean_beta, &faulted_beta, "fault-injected two-server run");
}

// ---------------------------------------------------------------------
// ps-stats labelling: each fleet member announces its shard range.
// ---------------------------------------------------------------------

#[test]
fn ps_stats_snapshot_labels_the_servers_shard_range() {
    let (host, addr) = loopback_host();
    let bytes = Arc::new(AtomicU64::new(0));
    let mut coord = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
    coord
        .init_routed(7, 1, 1, StalenessPolicy::Bounded(0), &[(100, 50)], 0, 1, 2)
        .unwrap();
    let snap = coord.obs_stats().unwrap();
    let text = snap.render();
    assert!(
        text.contains("shards = [100..150)"),
        "ps-stats must banner the hosted shard range:\n{text}"
    );
    assert!(text.contains("route.index = 1"), "{text}");
    assert!(text.contains("route.servers = 2"), "{text}");
    host.stop();
}
