//! Observability-contract suite: the obs layer must be *free*. A
//! staleness-0 run with the metrics registry and span tracing fully on
//! (level 2 + an events file) must be bitwise identical — every trace
//! point's objective, bit for bit — to the same run with observability
//! fully off (level 0). The layer only observes: counters are atomics
//! the meters already incremented, gate timing never feeds arithmetic,
//! and span events go to a side-channel ring. Also pins the event-file
//! schema: every line is valid JSON in the chrome://tracing event
//! format, all seven phases appear, and the plan-phase durations sum to
//! the report's `sched_wait_total`.

use std::path::PathBuf;
use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::obs::{Phase, SpanEvent};
use strads::util::Json;
use strads::workers::{run_distributed, DistributedReport};

/// A fresh path for a per-test events file (removed up front so the
/// append-mode flush starts from empty).
fn events_path(tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("strads_obs_{}_{}.jsonl", tag, std::process::id()));
    let _ = std::fs::remove_file(&path);
    path
}

fn obj_bits(report: &DistributedReport) -> Vec<u64> {
    report.trace.points.iter().map(|p| p.objective.to_bits()).collect()
}

/// Parse the events file back into spans, asserting every line is valid
/// JSON with the full span schema.
fn load_spans(path: &PathBuf) -> Vec<SpanEvent> {
    let text = std::fs::read_to_string(path).expect("events file written");
    text.lines()
        .map(|line| {
            let j = Json::parse(line).expect("every event line is valid JSON");
            SpanEvent::from_json(&j).expect("every event line carries the span schema")
        })
        .collect()
}

#[test]
fn lasso_staleness0_is_bitwise_identical_with_obs_on_and_off() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let rounds = 80;
    let run = |cfg: &RunConfig| {
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, cfg, rounds, "tiny").unwrap();
        (report, problem.beta().to_vec())
    };

    let path = events_path("lasso");
    let mut on = RunConfig { workers: 4, lambda: 1e-3, ..Default::default() };
    on.sap.shards = 2;
    on.obs.level = 2;
    on.obs.events_path = path.to_string_lossy().into_owned();
    let mut off = on.clone();
    off.obs.level = 0;
    off.obs.events_path.clear();

    let (r_on, beta_on) = run(&on);
    let (r_off, beta_off) = run(&off);

    // The acceptance pin: full observability changes *nothing*.
    assert_eq!(obj_bits(&r_on), obj_bits(&r_off), "objective trajectory must be bitwise equal");
    for (j, (a, b)) in beta_on.iter().zip(&beta_off).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "beta[{j}] diverged under observation: {a} vs {b}");
    }
    assert_eq!(r_on.pull_bytes, r_off.pull_bytes);
    assert_eq!(r_on.gate_waits, r_off.gate_waits);

    // Level 2 exposes the registry through the report; level 0 is empty.
    assert!(!r_on.obs_metrics.is_empty(), "obs-on report must carry the registry snapshot");
    assert!(r_off.obs_metrics.is_empty(), "obs-off report must carry no metrics");
    let metric = |name: &str| {
        r_on.obs_metrics
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("registry must export {name}"))
            .1
            .as_u64()
    };
    assert!(metric("ps.pulls") > 0);
    assert_eq!(metric("ps.pull_bytes"), r_on.pull_bytes, "report fields are registry views");

    // The trace file: valid JSONL, all seven phases, and the timeline's
    // plan lane cross-checks the report's scheduler-wait accumulator.
    let spans = load_spans(&path);
    for phase in Phase::ALL {
        assert!(
            spans.iter().any(|s| s.phase == phase),
            "phase {:?} missing from the timeline",
            phase
        );
    }
    let plan_secs: f64 =
        spans.iter().filter(|s| s.phase == Phase::Plan).map(|s| s.dur_us as f64 / 1e6).sum();
    // Each span duration truncates to whole microseconds, so the sum
    // undershoots by at most one microsecond per planned round.
    let tol = rounds as f64 * 1e-6 + 1e-9;
    assert!(
        (r_on.sched_wait_total - plan_secs) <= tol && plan_secs <= r_on.sched_wait_total + tol,
        "plan spans sum to {plan_secs}s but the report says {}s",
        r_on.sched_wait_total
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn mf_staleness0_is_bitwise_identical_with_obs_on_and_off() {
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let run = |cfg: &RunConfig| {
        let mut problem = DistMf::new(&data.a, 4, 0.05, 32);
        let rounds = problem.rounds_for_iters(3);
        run_distributed(&mut problem, cfg, rounds, "tiny").unwrap()
    };

    let path = events_path("mf");
    let mut on = RunConfig { workers: 4, ..Default::default() };
    on.obs.level = 2;
    on.obs.events_path = path.to_string_lossy().into_owned();
    let mut off = on.clone();
    off.obs.level = 0;
    off.obs.events_path.clear();

    let r_on = run(&on);
    let r_off = run(&off);

    assert_eq!(
        r_on.trace.final_objective().to_bits(),
        r_off.trace.final_objective().to_bits(),
        "MF objective must be bitwise equal under observation: {} vs {}",
        r_on.trace.final_objective(),
        r_off.trace.final_objective()
    );
    assert_eq!(obj_bits(&r_on), obj_bits(&r_off));
    assert_eq!(r_on.rounds, r_off.rounds);
    assert!(!r_on.obs_metrics.is_empty());
    assert!(r_off.obs_metrics.is_empty());

    // MF timelines carry the same seven-phase schema.
    let spans = load_spans(&path);
    assert!(spans.iter().any(|s| s.phase == Phase::Compute));
    assert!(spans.iter().any(|s| s.phase == Phase::Apply));
    let _ = std::fs::remove_file(&path);
}
