//! Transport-parity suite for the parameter-server wire: the same
//! distributed runs over the in-process transport and over TCP to a
//! loopback-hosted server must be *bitwise* identical at staleness 0
//! (the f32/f64 wire is lossless by construction), the error paths must
//! surface cleanly when the server dies (no hangs), and the binary
//! protocol must round-trip arbitrary messages exactly.

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use strads::config::RunConfig;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::data::mf_powerlaw::{self, MfSynthSpec};
use strads::lasso::NativeLasso;
use strads::mf::DistMf;
use strads::ps::transport::tcp::TcpTransport;
use strads::ps::transport::wire::{
    decode_reply, decode_request, encode_reply, encode_request, Reply, Request,
};
use strads::ps::transport::{Transport, TransportError};
use strads::ps::{Cell, PsTcpServer, PullSpec, RangePull, StalenessPolicy, TransportKind};
use strads::util::Rng;
use strads::workers::{run_distributed, DistributedReport};

/// A fresh loopback server on an ephemeral port.
fn loopback_host() -> (PsTcpServer, String) {
    let host = PsTcpServer::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = host.local_addr().to_string();
    (host, addr)
}

fn lasso_cfg(workers: usize) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = 2;
    cfg
}

fn run_lasso(cfg: &RunConfig, rounds: usize, seed: u64) -> (DistributedReport, Vec<f64>) {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), seed);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = run_distributed(&mut problem, cfg, rounds, "tiny").unwrap();
    (report, problem.beta().to_vec())
}

#[test]
fn lasso_staleness0_bitwise_identical_across_transports() {
    // The acceptance pin: a staleness-0 Lasso run over TCP (separate
    // server, loopback socket) reproduces the in-process run bit for
    // bit — same final objective, same beta bits. The f32 range slabs
    // and f64 cells cross the wire as exact little-endian images, so
    // any divergence would mean the transport corrupted state.
    let rounds = 120;
    let inproc_cfg = lasso_cfg(4);
    assert_eq!(inproc_cfg.ps.transport, TransportKind::InProc);
    let (inproc, inproc_beta) = run_lasso(&inproc_cfg, rounds, 42);

    let (host, addr) = loopback_host();
    let mut tcp_cfg = lasso_cfg(4);
    tcp_cfg.ps.transport = TransportKind::Tcp;
    tcp_cfg.ps.addr = addr;
    let (tcp, tcp_beta) = run_lasso(&tcp_cfg, rounds, 42);
    host.stop();

    assert_eq!(
        inproc.trace.final_objective(),
        tcp.trace.final_objective(),
        "staleness-0 trajectories must be bitwise identical across transports"
    );
    for (j, (a, b)) in inproc_beta.iter().zip(&tcp_beta).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "beta[{j}] diverged across transports: {a} vs {b}"
        );
    }
    // The modeled wire meters agree too (same serve path server-side)...
    assert_eq!(inproc.pull_bytes, tcp.pull_bytes);
    assert_eq!(inproc.bytes_flushed, tcp.bytes_flushed);
    assert_eq!(inproc.bytes_republished, tcp.bytes_republished);
    // ...but only the TCP run moved real socket traffic, and at least
    // the modeled payload's worth of it (frames add headers on top).
    assert_eq!(inproc.socket_bytes, 0, "in-process must not touch sockets");
    assert_eq!((inproc.transport, tcp.transport), ("inproc", "tcp"));
    assert!(
        tcp.socket_bytes > tcp.pull_bytes,
        "real socket bytes ({}) must exceed the modeled pull payload ({})",
        tcp.socket_bytes,
        tcp.pull_bytes
    );
}

#[test]
fn mf_staleness0_bitwise_identical_across_transports() {
    // Same pin for the second problem family: CCD++ MF rank sweeps,
    // whose canonical state is f32 on both ends of the wire.
    let data = mf_powerlaw::generate(&MfSynthSpec::tiny(), 31);
    let run = |cfg: &RunConfig| {
        let mut problem = DistMf::new(&data.a, 4, 0.05, 32);
        let rounds = problem.rounds_for_iters(3);
        run_distributed(&mut problem, cfg, rounds, "tiny").unwrap()
    };
    let inproc_cfg = RunConfig { workers: 4, ..Default::default() };
    let inproc = run(&inproc_cfg);

    let (host, addr) = loopback_host();
    let mut tcp_cfg = RunConfig { workers: 4, ..Default::default() };
    tcp_cfg.ps.transport = TransportKind::Tcp;
    tcp_cfg.ps.addr = addr;
    let tcp = run(&tcp_cfg);
    host.stop();

    assert_eq!(
        inproc.trace.final_objective().to_bits(),
        tcp.trace.final_objective().to_bits(),
        "MF objectives must match bitwise: {} vs {}",
        inproc.trace.final_objective(),
        tcp.trace.final_objective()
    );
    assert_eq!(inproc.rounds, tcp.rounds);
    assert!(tcp.socket_bytes > 0);
}

#[test]
fn wire_compression_is_bitwise_invisible_and_cuts_socket_bytes() {
    // The v5 run encoding's standing contract: same trajectory bit for
    // bit with compression on or off (covered keys are f32-lossless on
    // the wire because the store applies deltas in f32 anyway), with
    // only the real socket traffic shrinking. The modeled meters must
    // not move at all — they count payloads, not frames.
    let rounds = 80;
    let run_with = |compress: bool, chunk_cells: usize| {
        let (host, addr) = loopback_host();
        let mut cfg = lasso_cfg(4);
        cfg.ps.transport = TransportKind::Tcp;
        cfg.ps.addr = addr;
        cfg.ps.wire_compress = compress;
        cfg.ps.chunk_cells = chunk_cells;
        let out = run_lasso(&cfg, rounds, 42);
        host.stop();
        out
    };
    let (plain, plain_beta) = run_with(false, 0);
    let (packed, packed_beta) = run_with(true, 0);
    assert_eq!(
        plain.trace.final_objective().to_bits(),
        packed.trace.final_objective().to_bits(),
        "compression must be bitwise invisible to the trajectory"
    );
    for (j, (a, b)) in plain_beta.iter().zip(&packed_beta).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "beta[{j}] diverged under compression: {a} vs {b}");
    }
    assert_eq!(plain.runs_encoded, 0, "wire_compress=off must encode no runs");
    assert!(packed.runs_encoded > 0, "the compressed run must actually emit runs");
    assert!(
        packed.socket_bytes < plain.socket_bytes,
        "run encoding must cut real socket bytes: {} (on) vs {} (off)",
        packed.socket_bytes,
        plain.socket_bytes
    );
    // The modeled meters are frame-format independent by design.
    assert_eq!(plain.pull_bytes, packed.pull_bytes);
    assert_eq!(plain.bytes_flushed, packed.bytes_flushed);
    assert_eq!(plain.bytes_republished, packed.bytes_republished);

    // Chunked slabs + compression together stay on the same trajectory.
    let (chunked, chunked_beta) = run_with(true, 16);
    assert_eq!(
        plain.trace.final_objective().to_bits(),
        chunked.trace.final_objective().to_bits(),
        "chunk_cells must be bitwise invisible over TCP"
    );
    for (j, (a, b)) in plain_beta.iter().zip(&chunked_beta).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "beta[{j}] diverged under chunking: {a} vs {b}");
    }
    assert_eq!(plain.pull_bytes, chunked.pull_bytes, "modeled pull meter is chunk-invariant");
}

#[test]
fn killed_server_surfaces_clean_errors_not_hangs() {
    // Client-level: a live connection whose server dies mid-run must
    // error out of every call — including a pull *blocked at the SSP
    // gate* — rather than hang.
    let (host, addr) = loopback_host();
    let bytes = Arc::new(AtomicU64::new(0));
    let mut coord = TcpTransport::connect(&addr, 0, Arc::clone(&bytes)).unwrap();
    coord.init(1, 4, 1, StalenessPolicy::Bounded(0), &[(0, 8)], 0).unwrap();
    coord.publish_range(0, &[0.0; 8], 0).unwrap();

    // This pull is 5 rounds ahead of the applied clock under a bound of
    // 0: it parks at the server-side gate until the kill releases it.
    let gated = {
        let mut worker = TcpTransport::connect(&addr, 0, bytes).unwrap();
        std::thread::spawn(move || worker.pull(&PullSpec::from_ranges(vec![(0, 8)]), 5))
    };
    std::thread::sleep(std::time::Duration::from_millis(50));
    host.stop();
    let err = gated.join().expect("no panic").unwrap_err();
    assert!(
        matches!(err, TransportError::Io(_) | TransportError::Shutdown),
        "gated pull must fail cleanly, got {err}"
    );
    assert!(coord.stats().is_err(), "the dead server cannot serve stats");

    // Run-level: a run pointed at an address nobody serves fails fast
    // with a connection error instead of spawning workers.
    let dead_addr = {
        let (host, addr) = loopback_host();
        host.stop();
        addr
    };
    let mut cfg = lasso_cfg(2);
    cfg.ps.transport = TransportKind::Tcp;
    cfg.ps.addr = dead_addr;
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 7);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let err = run_distributed(&mut problem, &cfg, 10, "tiny").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("transport") || msg.contains("refused"), "unhelpful error: {msg}");
}

/// Comparable image of a pulled range (f32 bits, so -0.0 != 0.0 and
/// NaN payloads count).
fn range_image(r: &RangePull) -> (usize, u64, Vec<u32>) {
    (r.start(), r.version(), r.values().iter().map(|v| v.to_bits()).collect())
}

#[test]
fn wire_protocol_roundtrips_random_messages() {
    // Property test: 200 seeded-random requests and pull replies must
    // survive encode -> decode exactly. Values are drawn to include
    // negatives, zeros, subnormals and huge magnitudes.
    fn rand_f64(rng: &mut Rng) -> f64 {
        match rng.below(5) {
            0 => 0.0,
            1 => -0.0,
            2 => (rng.f64() - 0.5) * 1e300,
            3 => f64::MIN_POSITIVE * rng.f64(),
            _ => rng.normal(),
        }
    }
    let mut rng = Rng::new(0xD15C0);
    for case in 0..200 {
        // -- request: a random pull spec --
        let nranges = rng.below(4);
        let ranges: Vec<(usize, usize)> =
            (0..nranges).map(|_| (rng.below(1 << 20), rng.below(64))).collect();
        let keys: Vec<usize> = (0..rng.below(8)).map(|_| rng.below(1 << 30)).collect();
        let req = Request::Pull {
            worker: rng.below(64),
            round: rng.next_u64(),
            spec: PullSpec { ranges: ranges.clone(), keys },
        };
        let decoded = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(decoded, req, "case {case}");

        // -- request: a random delta batch --
        let deltas: Vec<(usize, f64)> =
            (0..rng.below(16)).map(|_| (rng.below(1 << 24), rand_f64(&mut rng))).collect();
        let req = Request::Flush {
            worker: rng.below(64),
            block: rng.next_u64(),
            round: rng.next_u64(),
            seq: rng.next_u64(),
            deltas,
        };
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req, "case {case}");

        // -- reply: a random pull result --
        let reply_ranges: Vec<RangePull> = ranges
            .iter()
            .map(|&(start, len)| {
                let values: Vec<f32> =
                    (0..len).map(|_| rand_f64(&mut rng) as f32).collect();
                RangePull::owned(start, rng.next_u64(), values)
            })
            .collect();
        let cells: Vec<Cell> = (0..rng.below(8))
            .map(|_| Cell { version: rng.next_u64(), value: rand_f64(&mut rng) })
            .collect();
        let reply = Reply::Pull {
            gap: rng.next_u64(),
            waited: rng.below(2) == 1,
            gate_us: rng.next_u64(),
            ranges: reply_ranges,
            cells,
        };
        let decoded = decode_reply(&encode_reply(&reply)).unwrap();
        let (Reply::Pull { gap, waited, gate_us, ranges: dr, cells: dc },
             Reply::Pull { gap: g0, waited: w0, gate_us: u0, ranges: or, cells: oc }) =
            (decoded, reply)
        else {
            panic!("wrong reply kind");
        };
        assert_eq!((gap, waited, gate_us), (g0, w0, u0), "case {case}");
        let dr: Vec<_> = dr.iter().map(range_image).collect();
        let or: Vec<_> = or.iter().map(range_image).collect();
        assert_eq!(dr, or, "case {case}: range images must round-trip bitwise");
        let bits = |cs: &[Cell]| -> Vec<(u64, u64)> {
            cs.iter().map(|c| (c.version, c.value.to_bits())).collect()
        };
        assert_eq!(bits(&dc), bits(&oc), "case {case}: cells must round-trip bitwise");
    }
}

#[test]
fn one_server_process_hosts_back_to_back_runs() {
    // The staleness sweep reuses a single ps-server for every setting:
    // each run re-Inits the host. Two consecutive runs with different
    // staleness policies must both complete and stay correct.
    let (host, addr) = loopback_host();
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 9);
    let mut last_objective = None;
    for setting in ["0", "2"] {
        let mut cfg = lasso_cfg(3);
        cfg.ps.transport = TransportKind::Tcp;
        cfg.ps.addr = addr.clone();
        cfg.ps.set_staleness_arg(setting).unwrap();
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = run_distributed(&mut problem, &cfg, 60, "tiny").unwrap();
        assert_eq!(report.rounds, 60, "staleness={setting} stopped early");
        let first = report.trace.points.first().unwrap().objective;
        let last = report.trace.final_objective();
        assert!(last < first, "staleness={setting}: {first} -> {last}");
        last_objective = Some(last);
    }
    assert!(last_objective.is_some());
    host.stop();
}
