//! Regression pins for the pipelined sharded scheduler service:
//! staleness-0 bit-exactness with the engine path, shard-rotation
//! determinism, the inline fallback's equivalence, and the
//! `--scheduler static|random` distributed routing fix.

use std::sync::Arc;
use strads::config::RunConfig;
use strads::coordinator::priority::PriorityKind;
use strads::data::lasso_synth::{self, LassoSynthSpec};
use strads::lasso::NativeLasso;
use strads::prelude::*;
use strads::sched_service::{OracleDeps, PlannerSet, SchedService};

fn lasso_cfg(workers: usize, sap_shards: usize) -> RunConfig {
    let mut cfg = RunConfig { workers, lambda: 1e-3, ..Default::default() };
    cfg.sap.shards = sap_shards;
    cfg
}

/// The tentpole acceptance pin: staleness-0 distributed Lasso with
/// pipelined sharded planning enabled (the default) must follow the
/// engine path's objective trajectory *exactly* — same plans from the
/// shard threads, same snapshots, same apply order, same arithmetic.
#[test]
fn staleness0_pipelined_sharded_planning_is_bit_exact_with_engine() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 42);
    let cfg = lasso_cfg(4, 2);
    let rounds = 120;

    let mut dist_problem = NativeLasso::new(&data, cfg.lambda);
    let report =
        strads::workers::run_distributed(&mut dist_problem, &cfg, rounds, "tiny").unwrap();
    assert!(report.sched_service_used, "the service must be planning this run");

    // Engine semantics: the identical scheduler config, serial.
    let mut local = NativeLasso::new(&data, cfg.lambda);
    let mut sched = DynamicScheduler::new(local.num_vars(), &cfg.sap, cfg.engine.seed);
    let mut engine_objs = Vec::new();
    for _ in 0..rounds {
        let blocks = sched.plan(&mut local, cfg.workers);
        if blocks.is_empty() {
            break;
        }
        let res = local.update_blocks(&blocks);
        sched.observe(&res);
        engine_objs.push(res.objective.expect("lasso maintains an incremental objective"));
    }

    // Per-round objectives must track the engine trajectory to within
    // the β-reconstruction rounding (β += δ on the distributed path —
    // the one documented arithmetic difference; anything looser means
    // a plan diverged or an apply reordered). record_every = 1, so
    // every round is pinned.
    assert_eq!(report.rounds, engine_objs.len());
    for pt in &report.trace.points {
        if pt.round < engine_objs.len() {
            let want = engine_objs[pt.round];
            assert!(
                (pt.objective - want).abs() <= 1e-12 * want.abs().max(1.0),
                "round {}: engine {} vs distributed {}",
                pt.round,
                want,
                pt.objective
            );
        }
    }
    // And the final exact recompute agrees as tightly.
    let local_obj = local.objective();
    let dist_obj = report.trace.final_objective();
    assert!(
        (local_obj - dist_obj).abs() <= 1e-12 * local_obj.abs().max(1.0),
        "final {local_obj} vs {dist_obj}"
    );
}

/// Same seed + same shard count ⇒ identical plan streams, from both
/// the serial rotation and the threaded service (lock-step delivery).
#[test]
fn shard_rotation_is_deterministic_across_runs_and_execution_shapes() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 7);
    let problem = NativeLasso::new(&data, 1e-3);
    let oracle = problem.sched_oracle().expect("lasso exposes an oracle");
    let sap = strads::config::SapConfig { shards: 3, ..Default::default() };
    let (shards, p, seed, rounds) = (3usize, 4usize, 11u64, 18usize);

    let drive_service = |oracle: Arc<dyn SchedOracle>| -> Vec<Vec<Block>> {
        let mut svc = SchedService::spawn(
            oracle,
            SchedKind::Dynamic,
            PriorityKind::Linear,
            &sap,
            seed,
            shards,
            p,
            0, // lock-step observation contract
            2,
        );
        let mut plans = Vec::new();
        for _ in 0..rounds {
            let (plan, _wait) = svc.pop_plan().unwrap();
            let deltas: Vec<(usize, f64)> = plan
                .iter()
                .flat_map(|b| b.vars.iter().map(|&v| (v, (v % 7) as f64 * 0.1)))
                .collect();
            svc.observe(Arc::new(deltas));
            plans.push(plan);
        }
        plans
    };

    let a = drive_service(Arc::clone(&oracle));
    let b = drive_service(Arc::clone(&oracle));
    assert_eq!(a, b, "same seed + shard count must replay identically");

    // The serial rotation over the same planners produces the same
    // stream — the two execution shapes are one scheduling stack.
    let mut serial =
        PlannerSet::new(oracle.num_vars(), shards, SchedKind::Dynamic, PriorityKind::Linear, &sap, seed);
    for (round, plan) in a.iter().enumerate() {
        let serial_plan = serial.plan_turn(&mut OracleDeps(&*oracle), p);
        assert_eq!(&serial_plan, plan, "round {round}: serial vs service diverged");
        let deltas: Vec<(usize, f64)> = serial_plan
            .iter()
            .flat_map(|b| b.vars.iter().map(|&v| (v, (v % 7) as f64 * 0.1)))
            .collect();
        serial.observe(&RoundResult { deltas, ..Default::default() });
    }
}

/// Turning the service off (inline coordinator planning) must not
/// change staleness-0 results — only who computes the plan. Both arms
/// run the identical planner set (same policy, shard count, seed), so
/// this holds for every scheduler kind, not just the dynamic one.
#[test]
fn inline_fallback_matches_service_path_at_staleness0() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 13);
    let rounds = 80;
    let run = |kind: SchedKind, service: bool| -> f64 {
        let mut cfg = lasso_cfg(4, 2);
        cfg.sched.kind = kind;
        cfg.sched.service = service;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report =
            strads::workers::run_distributed(&mut problem, &cfg, rounds, "tiny").unwrap();
        assert_eq!(report.sched_service_used, service);
        report.trace.final_objective()
    };
    for kind in [SchedKind::Dynamic, SchedKind::Static, SchedKind::Random] {
        let on = run(kind, true);
        let off = run(kind, false);
        assert_eq!(on.to_bits(), off.to_bits(), "{kind:?}: service {on} vs inline {off}");
    }
}

/// The `--scheduler static|random` routing fix: the distributed path
/// must honor the configured scheduler kind instead of hardcoding the
/// dynamic one (all three kinds run on real worker threads).
#[test]
fn static_and_random_schedulers_run_distributed() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 19);
    for kind in [SchedKind::Static, SchedKind::Random] {
        let mut cfg = lasso_cfg(4, 2);
        cfg.sched.kind = kind;
        let mut problem = NativeLasso::new(&data, cfg.lambda);
        let report = strads::workers::run_distributed(&mut problem, &cfg, 150, "tiny")
            .unwrap_or_else(|e| panic!("{kind:?} failed distributed: {e}"));
        assert!(report.rounds > 0, "{kind:?} planned nothing");
        assert!(report.deltas_applied > 0, "{kind:?} applied nothing");
        let first = report.trace.points.first().unwrap().objective;
        let last = report.trace.final_objective();
        assert!(last.is_finite(), "{kind:?} diverged");
        // Static keeps the rho depcheck, so it must genuinely optimize;
        // random (Shotgun) merely has to run to completion at s = 0.
        if kind == SchedKind::Static {
            assert!(last < first * 0.95, "{kind:?}: first {first} last {last}");
        }
    }
}

/// Per-round `sched_wait` is surfaced, `vtime` excludes it, and the
/// distributed imbalance column carries measured (not just planned)
/// straggler ratios.
#[test]
fn trace_separates_scheduling_from_compute() {
    let data = lasso_synth::generate(&LassoSynthSpec::tiny(), 23);
    let cfg = lasso_cfg(4, 2);
    let mut problem = NativeLasso::new(&data, cfg.lambda);
    let report = strads::workers::run_distributed(&mut problem, &cfg, 80, "tiny").unwrap();
    assert!(report.sched_wait_total > 0.0, "lock-step planning always waits some");
    let mut any_wait = false;
    for pt in &report.trace.points {
        assert!(pt.sched_wait >= 0.0 && pt.sched_wait.is_finite());
        assert!(pt.imbalance >= 1.0 - 1e-9, "imbalance ratio below 1: {}", pt.imbalance);
        assert!(
            pt.vtime <= pt.wtime + 1e-12,
            "vtime {} must not exceed wtime {}",
            pt.vtime,
            pt.wtime
        );
        any_wait |= pt.sched_wait > 0.0;
    }
    assert!(any_wait, "at least one round must record a nonzero sched_wait");
}
