//! Fig 5 bench: single-machine parallel MF —
//! {balanced (STRADS), uniform (no LB)} x {netflix-like, yahoo-like}
//! x {4, 8, 16} cores.
//!
//! The claim under test: load balancing shortens cluster time for the
//! same updates; the gain grows with nnz skew and (for yahoo-like)
//! with core count.

use strads::config::{CostModelConfig, EngineConfig};
use strads::data::mf_powerlaw::{self, gini};
use strads::experiments;
use strads::metrics::Trace;
use strads::mf::{run_mf, MfPartition, NativeMf};

fn main() {
    let iters: usize = std::env::var("STRADS_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    println!("== Fig 5: parallel MF, LB vs no-LB ({iters} CCD iterations/panel) ==\n");
    println!(
        "{:<9} {:>5} {:<9} {:>14} {:>11} {:>10} {:>9}",
        "dataset", "P", "blocks", "final obj", "vtime(s)", "straggler", "wall(s)"
    );
    let cost = CostModelConfig::default();
    for dataset in ["netflix", "yahoo"] {
        let spec = experiments::mf_spec(dataset).unwrap();
        let data = mf_powerlaw::generate(&spec, 42);
        let g = gini(&data.a.col_nnz());
        let mut speedups = Vec::new();
        for &workers in &[4usize, 8, 16] {
            let mut vtimes = Vec::new();
            for part in [MfPartition::Balanced, MfPartition::Uniform] {
                let mut backend = NativeMf::new(&data.a, data.rank_true, 0.05, 43);
                let ecfg =
                    EngineConfig { max_rounds: iters, record_every: 1, ..Default::default() };
                let mut t = Trace::new(part.name(), dataset, workers);
                let wall = std::time::Instant::now();
                run_mf(&mut backend, part, workers, &ecfg, &cost, &mut t);
                println!(
                    "{:<9} {:>5} {:<9} {:>14.6e} {:>11.3} {:>10.2} {:>9.1}",
                    dataset,
                    workers,
                    part.name(),
                    t.final_objective(),
                    t.final_vtime(),
                    t.points.last().map(|p| p.imbalance).unwrap_or(1.0),
                    wall.elapsed().as_secs_f64()
                );
                vtimes.push(t.final_vtime());
            }
            speedups.push(vtimes[1] / vtimes[0]);
        }
        println!(
            "  {dataset}: col-nnz gini {g:.2}; LB speedup by P: {:.2}x / {:.2}x / {:.2}x\n",
            speedups[0], speedups[1], speedups[2]
        );
    }
}
