//! Fig 1 bench: STRADS vs Shotgun convergence on the AD-regime Lasso.
//!
//! Prints the paper's series (objective at matched virtual-time
//! checkpoints) plus a time-to-quality summary. Set
//! `STRADS_BENCH_ROUNDS` to lengthen (default 600 keeps `cargo bench`
//! fast; the CLI `strads fig1` runs the full figure).

use strads::config::{EngineConfig, RunConfig};
use strads::experiments;

fn main() {
    let rounds: usize = std::env::var("STRADS_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(600);
    let cfg = RunConfig {
        workers: 32,
        lambda: 5e-4,
        engine: EngineConfig {
            max_rounds: rounds,
            record_every: 10,
            objective_every: 100,
            ..Default::default()
        },
        ..Default::default()
    };
    println!("== Fig 1: parallel Lasso, AD-regime, lambda=5e-4, P=32 ==");
    let wall = std::time::Instant::now();
    let traces = experiments::fig1(&cfg, None);
    let dynamic = &traces[0];
    let random = &traces[1];

    // objective at matched vtime checkpoints (paper plots these curves)
    println!("\n  vtime(s)   STRADS(dynamic)   Shotgun(random)");
    let t_end = dynamic.final_vtime().min(random.final_vtime());
    for frac in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let t = t_end * frac;
        let at = |tr: &strads::metrics::Trace| {
            tr.points
                .iter()
                .take_while(|p| p.vtime <= t)
                .last()
                .map(|p| p.objective)
                .unwrap_or(f64::NAN)
        };
        println!("  {:>8.2}   {:>15.6e}   {:>15.6e}", t, at(dynamic), at(random));
    }
    println!(
        "\nfinal: dynamic {:.6e} vs random {:.6e}  ({} rounds, wall {:.1}s)",
        dynamic.final_objective(),
        random.final_objective(),
        rounds,
        wall.elapsed().as_secs_f64()
    );
    if let Some(t) = dynamic.time_to_reach(random.final_objective()) {
        println!(
            "time-to-quality: dynamic reached random's final at vtime {:.2}s / random {:.2}s  ({:.1}x)",
            t,
            random.final_vtime(),
            random.final_vtime() / t.max(1e-12)
        );
    }
}
