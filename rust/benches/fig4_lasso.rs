//! Fig 4 bench: the 18-panel distributed Lasso sweep —
//! {dynamic, static, random} x {adlike, wide} x {60, 120, 240} cores.
//!
//! Prints one row per (dataset, P, scheduler) with final objective and
//! time-to-quality, and checks the paper's orderings. The CLI
//! (`strads fig4`) writes the full CSV curves; this bench uses a
//! reduced round budget sized for `cargo bench`.

use strads::config::{EngineConfig, RunConfig};
use strads::data::lasso_synth::generate;
use strads::experiments::{self, SchedKind};

fn main() {
    let rounds: usize = std::env::var("STRADS_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    println!("== Fig 4: distributed Lasso sweep ({rounds} rounds/panel) ==\n");
    println!(
        "{:<8} {:>5} {:<9} {:>14} {:>12} {:>10}",
        "dataset", "P", "sched", "final obj", "vtime(s)", "wall(s)"
    );
    let mut orderings_ok = 0;
    let mut orderings = 0;
    for dataset in ["adlike", "wide"] {
        let data = generate(&experiments::lasso_spec(dataset).unwrap(), 42);
        for &workers in &[60usize, 120, 240] {
            let mut finals = Vec::new();
            for sched in [SchedKind::Dynamic, SchedKind::Static, SchedKind::Random] {
                let cfg = RunConfig {
                    workers,
                    lambda: 5e-4,
                    engine: EngineConfig {
                        max_rounds: rounds,
                        record_every: 20,
                        objective_every: 100,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                let wall = std::time::Instant::now();
                let t = experiments::run_lasso_native(&data, dataset, sched, &cfg);
                println!(
                    "{:<8} {:>5} {:<9} {:>14.6e} {:>12.2} {:>10.1}",
                    dataset,
                    workers,
                    sched.name(),
                    t.final_objective(),
                    t.final_vtime(),
                    wall.elapsed().as_secs_f64()
                );
                finals.push(t.final_objective());
            }
            orderings += 1;
            if finals[0] <= finals[2] {
                orderings_ok += 1; // dynamic beats random (the headline)
            }
        }
    }
    println!("\npaper ordering (dynamic <= random): {orderings_ok}/{orderings} panels");
}
