//! Parameter-server storage micro-benchmarks: dense-segment slabs vs
//! the hashed shard path on the access patterns the distributed runs
//! actually produce — a contiguous residual-sized range read/publish
//! per pull (the Lasso hot path) and scattered β-delta pushes.

use strads::benchutil::{report, time_fn};
use strads::ps::{PullSpec, ShardedStore};

fn main() {
    println!("== ps storage micro-benchmarks (n = 65536, 8 shards) ==\n");
    let n = 65_536usize;
    let values: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let dense = ShardedStore::with_segments(8, &[(0, n)]);
    let hashed = ShardedStore::new(8);
    dense.publish_dense(&values, 0);
    hashed.publish_dense(&values, 0);

    // --- the per-pull residual read ---------------------------------
    let spec = PullSpec::from_ranges(vec![(0, n)]);
    let (med, min, max) = time_fn(3, 30, || {
        std::hint::black_box(dense.read_spec(&spec));
    });
    report(&format!("dense : read contiguous range ({n})"), med, min, max);
    let (med, min, max) = time_fn(3, 30, || {
        std::hint::black_box(hashed.read_spec(&spec));
    });
    report(&format!("hashed: read contiguous range ({n})"), med, min, max);

    // --- the full-resync publish ------------------------------------
    let (med, min, max) = time_fn(3, 30, || {
        dense.publish_dense(&values, 1);
    });
    report("dense : publish_dense full state", med, min, max);
    let (med, min, max) = time_fn(3, 30, || {
        hashed.publish_dense(&values, 1);
    });
    report("hashed: publish_dense full state", med, min, max);

    // --- the sparse tolerance-gated republish ------------------------
    let sparse: Vec<(usize, f64)> = (0..n / 16).map(|i| (i * 16, 0.25)).collect();
    let (med, min, max) = time_fn(3, 30, || {
        dense.publish(&sparse, 2);
    });
    report(&format!("dense : sparse publish ({} entries)", sparse.len()), med, min, max);
    let (med, min, max) = time_fn(3, 30, || {
        hashed.publish(&sparse, 2);
    });
    report(&format!("hashed: sparse publish ({} entries)", sparse.len()), med, min, max);

    // --- the worker β-delta push ------------------------------------
    let deltas: Vec<(usize, f64)> = (0..512).map(|i| ((i * 127) % n, 0.5)).collect();
    let (med, min, max) = time_fn(3, 50, || {
        dense.add_deltas(&deltas, 3);
    });
    report("dense : add_deltas 512 scattered", med, min, max);
    let (med, min, max) = time_fn(3, 50, || {
        hashed.add_deltas(&deltas, 3);
    });
    report("hashed: add_deltas 512 scattered", med, min, max);

    println!(
        "\nhash probes metered: dense = {} (must stay 0), hashed = {}",
        dense.hash_probes(),
        hashed.hash_probes()
    );
}
