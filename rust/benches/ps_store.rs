//! Parameter-server storage micro-benchmarks: f32 epoch segments vs
//! the hashed shard path on the access patterns the distributed runs
//! actually produce — a contiguous residual-sized range pull per round
//! (the Lasso hot path, now an O(1) `Arc` clone), full and sparse
//! republishes, scattered β-delta pushes, and the TCP wire codec on a
//! residual-sized pull reply (what a networked worker pays per round on
//! top of the store read).

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use strads::benchutil::{report, time_fn};
use strads::ps::transport::wire::{
    decode_reply, decode_request, encode_flush, encode_flush_maybe_runs, encode_reply, Reply,
    SegmentMap,
};
use strads::ps::transport::{InProcTransport, RouteMap, RoutedTransport, Transport};
use strads::ps::{Cell, ParameterServer, PullSpec, ShardedStore, StalenessPolicy};

fn main() {
    println!("== ps storage micro-benchmarks (n = 65536, 8 shards) ==\n");
    let n = 65_536usize;
    let values: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let dense = ShardedStore::with_segments(8, &[(0, n)]);
    let hashed = ShardedStore::new(8);
    dense.publish_dense(&values, 0);
    hashed.publish_dense(&values, 0);

    // --- ps_pull: the per-round residual read ------------------------
    // Arc-clone epoch view vs the representation it replaced vs the
    // hashed fallback. The replaced path served a covered range as
    // slab slice copies of 16-byte Cells into a fresh Vec (then
    // `values_f32` copied again); the honest baseline for the
    // acceptance ratio is therefore that contiguous Cell memcpy, timed
    // on an identical-size slab — not the (much slower) per-key
    // grouped read, which is reported separately for scattered access.
    let spec = PullSpec::from_ranges(vec![(0, n)]);
    let all_keys: Vec<usize> = (0..n).collect();
    let cell_slab: Vec<Cell> =
        (0..n).map(|i| Cell { version: 1, value: values[i] }).collect();
    let (arc_med, arc_min, arc_max) = time_fn(3, 50, || {
        std::hint::black_box(dense.read_spec(&spec));
    });
    report(&format!("ps_pull: dense Arc-clone range ({n})"), arc_med, arc_min, arc_max);
    let (cell_med, cell_min, cell_max) = time_fn(3, 50, || {
        let mut out: Vec<Cell> = Vec::with_capacity(n);
        out.extend_from_slice(&cell_slab);
        std::hint::black_box(out);
    });
    report(&format!("ps_pull: Cell slab slice copy   ({n})"), cell_med, cell_min, cell_max);
    let (med, min, max) = time_fn(3, 30, || {
        std::hint::black_box(dense.read(&all_keys));
    });
    report(&format!("ps_pull: dense per-key grouped  ({n})"), med, min, max);
    let (med, min, max) = time_fn(3, 30, || {
        std::hint::black_box(hashed.read_spec(&spec));
    });
    report(&format!("ps_pull: hashed fallback range  ({n})"), med, min, max);
    println!(
        "\nArc-clone vs replaced Cell-slice-copy read: {:.1}x faster (acceptance bar: >= 4x)\n",
        cell_med / arc_med.max(1e-12)
    );

    // --- the full-resync publish ------------------------------------
    let (med, min, max) = time_fn(3, 30, || {
        dense.publish_dense(&values, 1);
    });
    report("dense : publish_dense full state", med, min, max);
    let (med, min, max) = time_fn(3, 30, || {
        hashed.publish_dense(&values, 1);
    });
    report("hashed: publish_dense full state", med, min, max);

    // --- copy-on-publish: full resync while a reader holds the epoch -
    let (med, min, max) = time_fn(3, 30, || {
        let held = dense.read_range(0, n);
        dense.publish_dense(&values, 2);
        std::hint::black_box(held);
    });
    report("dense : publish_dense vs held epoch", med, min, max);

    // --- the sparse tolerance-gated republish ------------------------
    let sparse: Vec<(usize, f64)> = (0..n / 16).map(|i| (i * 16, 0.25)).collect();
    let (med, min, max) = time_fn(3, 30, || {
        dense.publish(&sparse, 3);
    });
    report(&format!("dense : sparse publish ({} entries)", sparse.len()), med, min, max);
    let (med, min, max) = time_fn(3, 30, || {
        hashed.publish(&sparse, 3);
    });
    report(&format!("hashed: sparse publish ({} entries)", sparse.len()), med, min, max);

    // --- the worker β-delta push ------------------------------------
    let deltas: Vec<(usize, f64)> = (0..512).map(|i| ((i * 127) % n, 0.5)).collect();
    let (med, min, max) = time_fn(3, 50, || {
        dense.add_deltas(&deltas, 4);
    });
    report("dense : add_deltas 512 scattered", med, min, max);
    let (med, min, max) = time_fn(3, 50, || {
        hashed.add_deltas(&deltas, 4);
    });
    report("hashed: add_deltas 512 scattered", med, min, max);

    // --- the tcp wire codec on a residual-sized pull reply -----------
    // Serialization cost a networked worker adds per round: one covered
    // range (n f32 cells -> raw LE bytes) each way. The encoded frame
    // is ~4 bytes/cell — the 4 B/cell pull accounting made literal.
    let pulled = dense.read_spec(&spec);
    let reply = Reply::Pull {
        gap: 0,
        waited: false,
        gate_us: 0,
        ranges: pulled.ranges,
        cells: pulled.cells,
    };
    let encoded = encode_reply(&reply);
    let (med, min, max) = time_fn(3, 50, || {
        std::hint::black_box(encode_reply(&reply));
    });
    report(&format!("wire  : encode pull reply ({n} f32)"), med, min, max);
    let (med, min, max) = time_fn(3, 50, || {
        std::hint::black_box(decode_reply(&encoded).expect("self-encoded reply"));
    });
    report(&format!("wire  : decode pull reply ({n} f32)"), med, min, max);
    println!(
        "wire  : encoded payload = {} bytes for {n} cells ({:.2} B/cell)",
        encoded.len(),
        encoded.len() as f64 / n as f64
    );

    // --- chunked pull under concurrent publish -----------------------
    // The MF-shaped race the chunked slabs exist for: a worker holds a
    // snapshot of the whole segment while the coordinator republishes a
    // narrow window. Whole-slab chunks (chunk_cells = 0) copy all n
    // cells per racing publish; 4096-cell chunks copy only the chunks
    // the window touches. Same arithmetic either way — only cow_bytes
    // moves.
    println!("\n== chunked epoch slabs: publish racing a held snapshot ==\n");
    let window: Vec<f64> = values[..1024].to_vec();
    for chunk_cells in [0usize, 4096] {
        let store = ShardedStore::with_segments_chunked(8, &[(0, n)], chunk_cells);
        store.publish_dense(&values, 0);
        let (med, min, max) = time_fn(3, 30, || {
            let held = store.read_range(0, n);
            store.publish_range(0, &window, 1);
            std::hint::black_box(held);
        });
        report(
            &format!("chunk_cells={chunk_cells:<5}: 1024-cell publish vs held {n}"),
            med,
            min,
            max,
        );
        println!(
            "    cow_clones = {}, cow_bytes = {} ({:.0} B/publish)",
            store.cow_clones(),
            store.cow_bytes(),
            store.cow_bytes() as f64 / store.cow_clones().max(1) as f64
        );
    }

    // --- wire codec: v5 run compression ratio ------------------------
    // Flush batches as the workers actually produce them: scattered
    // single-cell deltas (the Lasso β pushes) and a dense contiguous
    // stretch (the coordinator's windowed republish). Plain v4 frames
    // pay 16 B/entry; v5 runs pay ~8 B/entry scattered and ~4 B/cell
    // dense.
    println!("\n== wire codec: v5 run compression (vs plain 16 B/entry frames) ==\n");
    let map = SegmentMap::new(&[(0, n)]);
    let scattered: Vec<(usize, f64)> =
        (0..512).map(|i| ((i * 127) % n, values[(i * 127) % n])).collect();
    let dense_batch: Vec<(usize, f64)> = (0..4096).map(|i| (i, values[i])).collect();
    for (label, batch) in [("512 scattered", &scattered), ("4096 dense run", &dense_batch)] {
        let plain = encode_flush(0, 0, 0, 0, batch);
        let (compressed, runs) = encode_flush_maybe_runs(0, 0, 0, 0, batch, &map);
        let (med, min, max) = time_fn(3, 50, || {
            std::hint::black_box(encode_flush_maybe_runs(0, 0, 0, 0, batch, &map));
        });
        report(&format!("wire  : encode runs  ({label})"), med, min, max);
        let (med, min, max) = time_fn(3, 50, || {
            std::hint::black_box(decode_request(&compressed).expect("self-encoded"));
        });
        report(&format!("wire  : decode runs  ({label})"), med, min, max);
        println!(
            "    {} -> {} bytes ({:.2}x smaller, {runs} runs)",
            plain.len(),
            compressed.len(),
            plain.len() as f64 / compressed.len().max(1) as f64
        );
    }

    // --- routed fan-out: the split/merge tax at N servers ------------
    // What a RoutedTransport adds on top of the store reads: the
    // residual-sized range pull decomposed into N sub-ranges, pulled
    // per server, and reassembled into one owned image (N=1 vs the
    // Arc-clone read above isolates the copy the merge forces), plus a
    // scattered publish partitioned by owner.
    println!("\n== routed fan-out: split/merge overhead at N servers ==\n");
    for servers in [1usize, 2, 4] {
        let route = Arc::new(RouteMap::new(&[(0, n)], servers));
        let inner: Vec<Box<dyn Transport>> = (0..servers)
            .map(|i| {
                let host = Arc::new(ParameterServer::with_segments(
                    8,
                    1,
                    StalenessPolicy::Bounded(0),
                    &route.server_segments(i),
                ));
                Box::new(InProcTransport::new(host, 0)) as Box<dyn Transport>
            })
            .collect();
        let mut routed = RoutedTransport::new(inner, route, Arc::new(AtomicU64::new(0)));
        routed.publish_range(0, &values, 0).expect("in-proc publish");
        let (med, min, max) = time_fn(3, 30, || {
            std::hint::black_box(routed.pull(&spec, 0).expect("in-proc pull"));
        });
        report(&format!("route : split+merge pull {n}, N={servers}"), med, min, max);
        let (med, min, max) = time_fn(3, 30, || {
            routed.publish(&sparse, 5).expect("in-proc publish");
        });
        report(
            &format!("route : partitioned publish ({} entries), N={servers}", sparse.len()),
            med,
            min,
            max,
        );
    }

    println!(
        "\nhash probes metered: dense = {} (must stay 0), hashed = {}; \
         dense epoch cow-clones = {} (cow_bytes = {})",
        dense.hash_probes(),
        hashed.hash_probes(),
        dense.cow_clones(),
        dense.cow_bytes()
    );
}
