//! Scheduler micro-benchmarks (L3 hot path).
//!
//! The paper's §2 requirement: "the scheduler must be able to find
//! block structures faster than workers consume them". This bench
//! times every scheduler-side operation at fig4 scale (J = 4096,
//! P = 240, P' = 480), compares the total against the worker-side
//! round cost from the calibrated cost model, and measures what the
//! scheduler *service* buys: inline plan latency vs popping a
//! pipelined plan queue at S ∈ {1, 2, 4} shard threads.

use std::sync::Arc;
use strads::benchutil::{report, time_fn};
use strads::config::SapConfig;
use strads::coordinator::priority::PriorityKind;
use strads::coordinator::{merge_balanced, select_independent};
use strads::data::lasso_synth::{generate, LassoSynthSpec};
use strads::lasso::NativeLasso;
use strads::linalg::{axpy, dot};
use strads::problem::{Block, ModelProblem};
use strads::sched_service::{OracleDeps, PlannerSet, SchedService};
use strads::schedulers::{DynamicScheduler, SchedKind, Scheduler};
use strads::util::{Fenwick, Rng};

fn main() {
    println!("== scheduler micro-benchmarks (J=4096, P=240, P'=480) ==\n");
    let j = 4096;
    let p = 240;
    let p_prime = 480;
    let mut rng = Rng::new(1);

    // --- linalg kernels (the per-coordinate L1 hot path) ------------
    let n = 65_536usize;
    let va: Vec<f32> = (0..n).map(|i| (i as f32 * 0.001).sin()).collect();
    let vb: Vec<f32> = (0..n).map(|i| (i as f32 * 0.002).cos()).collect();
    let (med, min, max) = time_fn(3, 50, || {
        std::hint::black_box(dot(&va, &vb));
    });
    report(&format!("linalg: dot {n} (8-lane chunked)"), med, min, max);
    let mut vy = vb.clone();
    let (med, min, max) = time_fn(3, 50, || {
        axpy(0.5, &va, &mut vy);
        std::hint::black_box(&vy);
    });
    report(&format!("linalg: axpy {n} (8-lane chunked)"), med, min, max);

    // --- Fenwick ops ------------------------------------------------
    let weights: Vec<f64> = (0..j).map(|_| rng.f64() + 1e-6).collect();
    let mut fen = Fenwick::from_weights(&weights);
    let (med, min, max) = time_fn(3, 20, || {
        let mut r = Rng::new(7);
        for _ in 0..p_prime {
            std::hint::black_box(fen.sample(&mut r));
        }
    });
    report(&format!("fenwick: draw {p_prime} candidates"), med, min, max);

    let (med, min, max) = time_fn(3, 20, || {
        let mut r = Rng::new(8);
        std::hint::black_box(fen.sample_distinct(p_prime, &mut r));
    });
    report(&format!("fenwick: draw {p_prime} distinct (w/ removal)"), med, min, max);

    let (med, min, max) = time_fn(3, 20, || {
        for i in 0..p {
            fen.set(i * 17 % j, 0.5);
        }
    });
    report(&format!("fenwick: {p} priority updates"), med, min, max);

    // --- dependency check -------------------------------------------
    let c = p_prime;
    let mut dep = vec![0.0f64; c * c];
    for i in 0..c {
        for k in 0..c {
            if i != k {
                dep[i * c + k] = if (i + k) % 11 == 0 { 0.5 } else { 0.02 };
            }
        }
    }
    let cands: Vec<usize> = (0..c).collect();
    let (med, min, max) = time_fn(3, 20, || {
        std::hint::black_box(select_independent(&cands, &dep, 0.1, p));
    });
    report(&format!("depcheck: greedy select {p} of {c}"), med, min, max);

    // --- load balance -----------------------------------------------
    let blocks: Vec<Block> =
        (0..p_prime).map(|i| Block::singleton(i, (i % 37) as u64 + 1)).collect();
    let (med, min, max) = time_fn(3, 20, || {
        std::hint::black_box(merge_balanced(blocks.clone(), p));
    });
    report(&format!("balance: LPT merge {p_prime} -> {p}"), med, min, max);

    // --- whole plan() on the real problem ----------------------------
    let data = generate(&LassoSynthSpec::adlike(), 3);
    let mut problem = NativeLasso::new(&data, 5e-4);
    let cfg = SapConfig::default();
    let mut sched = DynamicScheduler::new(problem.num_vars(), &cfg, 5);
    // warm the dep cache the way a real run does
    for _ in 0..3 {
        let b = sched.plan(&mut problem, p);
        let r = problem.update_blocks(&b);
        sched.observe(&r);
    }
    let (med, min, max) = time_fn(2, 10, || {
        let b = sched.plan(&mut problem, p);
        let r = problem.update_blocks(&b);
        sched.observe(&r);
        std::hint::black_box(&r);
    });
    report("full SAP round: plan+update+observe (adlike)", med, min, max);
    let full_round_med = med;

    // --- plan latency: inline vs pipelined plan-queue pop -----------
    // The scheduler-service question: how long does the *coordinator*
    // spend per plan? Inline, it pays the full sampling + depcheck +
    // merge cost on its own thread; against the service it pays one
    // queue pop while S shard threads plan ahead concurrently.
    println!();
    let oracle = problem.sched_oracle().expect("lasso exposes a scheduling oracle");
    let nvars = problem.num_vars();
    for shards in [1usize, 2, 4] {
        let sap = SapConfig { shards, ..SapConfig::default() };
        let mut set =
            PlannerSet::new(nvars, shards, SchedKind::Dynamic, PriorityKind::Linear, &sap, 5);
        // warm the per-shard memo caches
        for _ in 0..shards {
            std::hint::black_box(set.plan_turn(&mut OracleDeps(&*oracle), p));
        }
        let (med, min, max) = time_fn(2, 10, || {
            std::hint::black_box(set.plan_turn(&mut OracleDeps(&*oracle), p));
        });
        report(&format!("plan latency: inline plan (S={shards})"), med, min, max);

        // Pipelined: unbounded observation slack keeps every shard
        // planning ahead, so the pop measures queue latency, the cost
        // the coordinator actually sits on.
        let mut svc = SchedService::spawn(
            Arc::clone(&oracle),
            SchedKind::Dynamic,
            PriorityKind::Linear,
            &sap,
            5,
            shards,
            p,
            u64::MAX,
            4,
        );
        // warm: let the shard threads fill their queues
        for _ in 0..shards * 2 {
            std::hint::black_box(svc.pop_plan().expect("shard thread alive"));
        }
        let (med, min, max) = time_fn(2, 10, || {
            std::hint::black_box(svc.pop_plan().expect("shard thread alive"));
        });
        report(&format!("plan latency: pipelined pop   (S={shards})"), med, min, max);
        drop(svc);
    }

    // --- the §2 bar ---------------------------------------------------
    let cost = strads::config::CostModelConfig::default();
    let worker_round = cost.sec_per_work_unit + cost.round_overhead_sec;
    println!(
        "\nworker round budget (cost model): {:.3} ms -> scheduler {} the bar",
        worker_round * 1e3,
        if full_round_med < worker_round * 4.0 { "CLEARS" } else { "MISSES" }
    );
}
