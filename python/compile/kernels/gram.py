"""L1 Pallas kernel for the dependency-check Gram matrix.

SAP step 2 needs pairwise coupling d(x_l, x_m) = |x_l^T x_m| over the P'
sampled candidate columns. We compute the full candidate Gram
G = X_cand^T X_cand in one kernel: the sample dimension is tiled into
ROW_TILE chunks and [P', P'] partial products accumulate in a VMEM block
revisited across the grid (same reduction pattern as lasso_cd).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128


def _gram_kernel(xc_ref, g_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    x = xc_ref[...]  # [T, C]
    g_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)


def gram(x_cand):
    """G = X_cand^T X_cand for a gathered candidate panel [N, C]."""
    n, c = x_cand.shape
    assert n % ROW_TILE == 0, f"N={n} must be a multiple of {ROW_TILE}"
    return pl.pallas_call(
        _gram_kernel,
        grid=(n // ROW_TILE,),
        in_specs=[pl.BlockSpec((ROW_TILE, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((c, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, c), jnp.float32),
        interpret=True,
    )(x_cand)
