"""Pure-jnp oracles for every L1 kernel -- the correctness ground truth.

pytest (python/tests/) asserts the Pallas kernels against these with
hypothesis-driven shape/value sweeps; the rust integration tests assert
the whole AOT artifact against rust-native reimplementations, so the
chain  pallas == ref == rust-native  pins all three layers together.
"""

import jax.numpy as jnp


def soft_threshold(g, lam):
    return jnp.sign(g) * jnp.maximum(jnp.abs(g) - lam, 0.0)


def cd_update_ref(x_sel, r, beta_sel, mask, lam):
    """Oracle for lasso_cd.cd_update (same shapes/returns)."""
    g = r.T @ x_sel + beta_sel  # [1, P]
    beta_new = jnp.where(mask > 0.0, soft_threshold(g, lam[0, 0]), beta_sel)
    delta = beta_new - beta_sel
    r_new = r - x_sel @ delta.T
    return beta_new, delta, r_new


def gram_ref(x_cand):
    """Oracle for gram.gram."""
    return x_cand.T @ x_cand


def rank1_update_ref(rt, mask, v, lam):
    """Oracle for mf_ccd.rank1_update (same shapes/returns)."""
    num = jnp.sum(mask * rt * v, axis=1, keepdims=True)
    den = jnp.sum(mask * (v * v), axis=1, keepdims=True)
    return num / (lam[0, 0] + den)


def lasso_objective_ref(x, y, beta, lam):
    """0.5 ||y - X beta||^2 + lam |beta|_1  (paper eq. 1, squared loss)."""
    r = y - x @ beta
    return 0.5 * jnp.sum(r * r) + lam * jnp.sum(jnp.abs(beta)), r


def mf_objective_ref(a, mask, w, h, lam):
    """sum_obs (a - wh)^2 + lam (||W||_F^2 + ||H||_F^2)  (paper eq. 3)."""
    r = (a - w @ h) * mask
    return jnp.sum(r * r) + lam * (jnp.sum(w * w) + jnp.sum(h * h))
