"""L1 Pallas kernels (lasso_cd, mf_ccd, gram) + pure-jnp oracles (ref)."""
