"""L1 Pallas kernel for the masked rank-1 CCD update (matrix factorization).

Paper eqs. (4)/(5): for rank t and each row i of a dispatched block,

    num_i = sum_j  mask_ij * rt_ij * v_j
    den_i = sum_j  mask_ij * v_j^2
    out_i = num_i / (lambda + den_i)

where rt = (A - WH) + w_t v^T is the residual with rank-t's own
contribution added back, and v is h_t (W update) or w_t (H update; the L2
graph transposes so the same kernel serves both sweeps).

The reduced dimension (M for W updates, N for H updates) is tiled into
COL_TILE chunks; num/den accumulate in VMEM blocks revisited across the
grid, and the division epilogue runs fused on the final step. Rows with no
observed entries get den = 0 -> out = 0/lambda = 0, matching the CCD
convention. Padded rows are masked by the caller (mask rows of zeros).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_TILE = 256


def _rank1_kernel(rt_ref, mask_ref, v_ref, lam_ref, num_ref, den_ref, out_ref):
    i = pl.program_id(0)
    nsteps = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    rt = rt_ref[...]  # [B, T]
    mk = mask_ref[...]  # [B, T]
    v = v_ref[...]  # [1, T]
    num_ref[...] += jnp.sum(mk * rt * v, axis=1, keepdims=True)
    den_ref[...] += jnp.sum(mk * (v * v), axis=1, keepdims=True)

    @pl.when(i == nsteps - 1)
    def _epilogue():
        out_ref[...] = num_ref[...] / (lam_ref[0, 0] + den_ref[...])


def rank1_update(rt, mask, v, lam):
    """Masked rank-1 CCD coefficient update.

    Args:
      rt:   [B, L] rank-t residual block (residual + own contribution).
      mask: [B, L] 0/1 observation mask (0 rows for bucket padding).
      v:    [1, L] the fixed factor vector (h_t or w_t).
      lam:  [1, 1] l2 penalty.

    Returns:
      out [B, 1]: the new w_t (or h_t) entries for the block's rows.
    """
    b, l = rt.shape
    # Largest standard tile that divides the reduced dim (the tiny test
    # shapes use 128-wide matrices).
    tile = COL_TILE if l % COL_TILE == 0 else 128
    assert l % tile == 0, f"L={l} must be a multiple of 128"
    grid = (l // tile,)
    _, _, out = pl.pallas_call(
        _rank1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, tile), lambda i: (0, i)),
            pl.BlockSpec((b, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=True,
    )(rt, mask, v, lam)
    return out
