"""L1 Pallas kernels for the batched Lasso coordinate-descent update.

The STRADS hot-spot for Lasso is, per dispatched block of P coordinates:

    g_j      = x_j^T r + beta_j                (unit-norm standardized x_j)
    beta_j'  = S(g_j, lambda)                  (soft threshold)
    r'       = r - X_sel (beta' - beta)        (residual rank-P update)

Both phases are written as TPU-shaped Pallas kernels: the sample dimension
N is tiled into ROW_TILE chunks streamed HBM->VMEM by BlockSpec; the
`X_sel^T r` contraction accumulates into a VMEM-resident [1, P] block
revisited at every grid step (the canonical Pallas reduction pattern) and
the soft-threshold epilogue runs fused on the final step. `interpret=True`
is mandatory on the CPU PJRT plugin -- real TPU lowering emits a Mosaic
custom-call the CPU client cannot execute; the interpret path lowers to
plain HLO so the rust runtime can run it anywhere.

Padded coordinate slots (shape-bucket capacity > live coordinates) carry
mask = 0 and are forced to keep their old beta, so padding is exact.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128


def _gth_kernel(xsel_ref, r_ref, beta_ref, mask_ref, lam_ref, bnew_ref):
    """Accumulate g += r_tile^T @ X_tile; soft-threshold on the last step.

    bnew_ref doubles as the [1, P] VMEM accumulator (holds the running g)
    and, after the epilogue, the new coefficient vector.
    """
    i = pl.program_id(0)
    nsteps = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        bnew_ref[...] = jnp.zeros_like(bnew_ref)

    # [1, T] @ [T, P] -> [1, P]: an MXU-shaped contraction per row tile.
    bnew_ref[...] += jnp.dot(
        r_ref[...].T, xsel_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(i == nsteps - 1)
    def _epilogue():
        lam = lam_ref[0, 0]
        g = bnew_ref[...] + beta_ref[...]
        thresh = jnp.sign(g) * jnp.maximum(jnp.abs(g) - lam, 0.0)
        bnew_ref[...] = jnp.where(mask_ref[...] > 0.0, thresh, beta_ref[...])


def _resid_kernel(xsel_ref, r_ref, delta_ref, out_ref):
    """r_tile' = r_tile - X_tile @ delta  (rank-P residual downdate)."""
    out_ref[...] = r_ref[...] - jnp.dot(
        xsel_ref[...], delta_ref[...], preferred_element_type=jnp.float32
    )


def cd_update(x_sel, r, beta_sel, mask, lam):
    """Batched soft-threshold CD update on a gathered coordinate panel.

    Args:
      x_sel:    [N, P] gathered covariate columns (standardized, unit norm).
      r:        [N, 1] current residual  y - X beta.
      beta_sel: [1, P] current coefficients of the selected coordinates.
      mask:     [1, P] 1.0 for live slots, 0.0 for bucket padding.
      lam:      [1, 1] l1 penalty.

    Returns:
      (beta_new [1, P], delta [1, P], r_new [N, 1]).
    """
    n, p = x_sel.shape
    assert n % ROW_TILE == 0, f"N={n} must be a multiple of {ROW_TILE}"
    grid = (n // ROW_TILE,)

    beta_new = pl.pallas_call(
        _gth_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, p), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=True,
    )(x_sel, r, beta_sel, mask, lam)

    delta = beta_new - beta_sel

    r_new = pl.pallas_call(
        _resid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_TILE, p), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((p, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(x_sel, r, delta.T)

    return beta_new, delta, r_new
