"""L2 JAX graphs: the compute-side of STRADS, lowered once to HLO text.

Each function here is one AOT artifact family. The graphs compose a
gather (coordinate/row/column selection chosen at runtime by the rust
scheduler and passed as an i32 index vector) with the L1 Pallas kernels
and the small residual/scatter algebra around them. Shape buckets larger
than the live selection are padded by the caller and neutralized by the
0/1 mask inputs, so every graph is exact for any live size <= capacity.

Conventions (all f32 unless noted):
  lasso_update(x[N,J], r[N,1], beta_sel[1,P], idx i32[P], mask[1,P],
               lam[1,1]) -> (beta_new[1,P], delta[1,P], r_new[N,1])
  lasso_gram(x[N,J], idx i32[C]) -> (g[C,C],)
  lasso_obj(x[N,J], y[N,1], beta[J,1], lam[1,1]) -> (obj[1,1], r[N,1])
  mf_update_w(a[N,M], mask[N,M], w[N,K], h[K,M], idx i32[B], rmask[B,1],
              t1h[K,1], lam[1,1]) -> (w_new[B,1], dw[B,1], w_next[N,K])
  mf_update_h(a[N,M], mask[N,M], w[N,K], h[K,M], idx i32[B], cmask[B,1],
              t1h[K,1], lam[1,1]) -> (h_new[B,1], dh[B,1], h_next[K,M])
  mf_obj(a[N,M], mask[N,M], w[N,K], h[K,M], lam[1,1]) -> (obj[1,1],)
"""

import jax.numpy as jnp

from compile.kernels import gram as gram_kernel
from compile.kernels import lasso_cd, mf_ccd

# ---------------------------------------------------------------- lasso --


def lasso_update(x, r, beta_sel, idx, mask, lam):
    """Batched CD update on the scheduler-selected coordinate set."""
    x_sel = jnp.take(x, idx, axis=1)  # [N, P]
    beta_new, delta, r_new = lasso_cd.cd_update(x_sel, r, beta_sel, mask, lam)
    return beta_new, delta, r_new


def lasso_gram(x, idx):
    """Candidate Gram matrix for SAP step-2 dependency checking."""
    x_cand = jnp.take(x, idx, axis=1)  # [N, C]
    return (gram_kernel.gram(x_cand),)


def lasso_obj(x, y, beta, lam):
    """Full objective + fresh residual (drift-correction / metrics path)."""
    r = y - x @ beta  # [N, 1]
    obj = 0.5 * jnp.sum(r * r) + lam[0, 0] * jnp.sum(jnp.abs(beta))
    return obj.reshape(1, 1), r


# ------------------------------------------------------------------- mf --


def mf_update_w(a, mask, w, h, idx, rmask, t1h, lam):
    """Rank-t CCD sweep over a load-balanced row block (paper eq. 4).

    Returns the new w_t entries for the block, their deltas, and the full
    updated W (scatter-add on device, so W round-trips as one buffer).
    Padding uses idx = 0 with rmask = 0: the masked delta is exactly zero,
    so the duplicate scatter-adds at row 0 are no-ops.
    """
    a_b = jnp.take(a, idx, axis=0)  # [B, M]
    mk_b = jnp.take(mask, idx, axis=0)  # [B, M]
    w_b = jnp.take(w, idx, axis=0)  # [B, K]
    pred = jnp.dot(w_b, h, preferred_element_type=jnp.float32)  # [B, M]
    w_t = w_b @ t1h  # [B, 1]
    h_t = t1h.T @ h  # [1, M]
    rt = a_b - pred + w_t @ h_t  # [B, M]
    w_new = mf_ccd.rank1_update(rt, mk_b, h_t, lam) * rmask
    dw = (w_new - w_t) * rmask  # [B, 1]
    w_next = w.at[idx].add(dw * t1h.T)  # adds only into column t
    return w_new, dw, w_next


def mf_update_h(a, mask, w, h, idx, cmask, t1h, lam):
    """Rank-t CCD sweep over a load-balanced column block (paper eq. 5).

    Same kernel as the W sweep, applied to the transposed block.
    """
    a_c = jnp.take(a, idx, axis=1).T  # [B, N]
    mk_c = jnp.take(mask, idx, axis=1).T  # [B, N]
    h_c = jnp.take(h, idx, axis=1)  # [K, B]
    pred = jnp.dot(w, h_c, preferred_element_type=jnp.float32).T  # [B, N]
    h_t = (t1h.T @ h_c).T  # [B, 1]
    w_t = (w @ t1h).T  # [1, N]
    rt = a_c - pred + h_t @ w_t  # [B, N]
    h_new = mf_ccd.rank1_update(rt, mk_c, w_t, lam) * cmask
    dh = (h_new - h_t) * cmask  # [B, 1]
    h_next = h.at[:, idx].add(t1h @ dh.T)  # adds only into row t
    return h_new, dh, h_next


def mf_obj(a, mask, w, h, lam):
    """Regularized squared error over observed entries (paper eq. 3)."""
    r = (a - jnp.dot(w, h, preferred_element_type=jnp.float32)) * mask
    obj = jnp.sum(r * r) + lam[0, 0] * (jnp.sum(w * w) + jnp.sum(h * h))
    return (obj.reshape(1, 1),)
