"""AOT driver: lower every (graph, shape-bucket) in shapes.py to HLO text.

Interchange format is HLO *text*, never a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` rust crate) rejects with
`proto.id() <= INT_MAX`; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Python runs exactly once, at build time (`make artifacts`); the rust
coordinator is self-contained afterwards. Alongside the .hlo.txt files we
emit `manifest.json`, which the rust `ArtifactStore` uses to discover
artifacts, their kinds, and their shape parameters (bucket capacities).

Usage:  cd python && python -m compile.aot --out ../artifacts [--only RE]
"""

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model, shapes


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _s(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def example_args(kind, p):
    """Abstract input signature for one artifact (shapes.py params)."""
    if kind == "lasso_update":
        n, j, cap = p["n"], p["j"], p["p"]
        return (
            _s((n, j)),          # x
            _s((n, 1)),          # r
            _s((1, cap)),        # beta_sel
            _s((cap,), jnp.int32),  # idx
            _s((1, cap)),        # mask
            _s((1, 1)),          # lam
        )
    if kind == "lasso_gram":
        n, j, c = p["n"], p["j"], p["c"]
        return (_s((n, j)), _s((c,), jnp.int32))
    if kind == "lasso_obj":
        n, j = p["n"], p["j"]
        return (_s((n, j)), _s((n, 1)), _s((j, 1)), _s((1, 1)))
    if kind in ("mf_update_w", "mf_update_h"):
        n, m, k, b = p["n"], p["m"], p["k"], p["b"]
        return (
            _s((n, m)),          # a
            _s((n, m)),          # mask
            _s((n, k)),          # w
            _s((k, m)),          # h
            _s((b,), jnp.int32),  # idx
            _s((b, 1)),          # rmask/cmask
            _s((k, 1)),          # t1h
            _s((1, 1)),          # lam
        )
    if kind == "mf_obj":
        n, m, k = p["n"], p["m"], p["k"]
        return (_s((n, m)), _s((n, m)), _s((n, k)), _s((k, m)), _s((1, 1)))
    raise ValueError(f"unknown artifact kind: {kind}")


GRAPHS = {
    "lasso_update": model.lasso_update,
    "lasso_gram": model.lasso_gram,
    "lasso_obj": model.lasso_obj,
    "mf_update_w": model.mf_update_w,
    "mf_update_h": model.mf_update_h,
    "mf_obj": model.mf_obj,
}


def build(out_dir: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    t_total = time.time()
    for name, kind, params in shapes.manifest_entries():
        if only and not re.search(only, name):
            continue
        t0 = time.time()
        args = example_args(kind, params)
        lowered = jax.jit(GRAPHS[kind]).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        inputs = [dict(shape=list(a.shape), dtype=a.dtype.name) for a in args]
        entries.append(
            dict(name=name, kind=kind, file=fname, params=params, inputs=inputs)
        )
        print(
            f"  {name}: {len(text) // 1024} KiB HLO in {time.time() - t0:.1f}s",
            file=sys.stderr,
        )
    manifest_path = os.path.join(out_dir, "manifest.json")
    if only and os.path.exists(manifest_path):
        # Partial rebuild: merge into the existing manifest so artifacts
        # outside the filter stay registered.
        with open(manifest_path) as f:
            old_entries = {e["name"]: e for e in json.load(f)["artifacts"]}
        for e in entries:
            old_entries[e["name"]] = e
        entries = list(old_entries.values())
    manifest = dict(
        version=1,
        row_tile=shapes.ROW_TILE,
        artifacts=entries,
    )
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"wrote {len(entries)} artifacts + manifest.json to {out_dir} "
        f"in {time.time() - t_total:.1f}s",
        file=sys.stderr,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    args = ap.parse_args()
    build(args.out, args.only)


if __name__ == "__main__":
    main()
