"""Canonical artifact shape manifest, shared by aot.py and the tests.

Every entry below becomes one AOT-compiled HLO artifact. Shapes are static
in XLA, so dynamic block sizes produced by the STRADS load balancer are
reconciled through *shape buckets*: each update graph is compiled at a
small set of capacity buckets and the rust runtime picks the smallest
bucket that fits, padding the remainder with masked slots (numerically
exact -- the kernels multiply padded lanes by a 0/1 mask).

Row counts (``n``) must be multiples of ``ROW_TILE`` (the Pallas row-tile)
because the L1 kernels tile the sample dimension; the data generators pad
with zero rows, which is exact for standardized regression (zero rows
contribute nothing to inner products or residuals).
"""

ROW_TILE = 128  # Pallas row-tile for the lasso kernels (sample dim)
COL_TILE = 256  # Pallas column-tile for the MF rank-1 kernel (reduced dim)

# ---------------------------------------------------------------- lasso --
# Dataset-shaped graph families. "adlike" mirrors the Alzheimer's-disease
# regime (few samples, many correlated covariates); "wide" mirrors the
# paper's wide synthetic set; "tiny" keeps tests and the quickstart fast.
LASSO_DATASETS = {
    "tiny": dict(n=128, j=256),
    "adlike": dict(n=512, j=4096),
    "wide": dict(n=384, j=8192),
}

# Coordinate-batch capacity buckets for the CD update graph (P slots).
LASSO_P_BUCKETS = {
    "tiny": (16,),
    "adlike": (16, 64, 256),
    "wide": (16, 64, 256),
}

# Candidate-set capacity buckets for the Gram (dependency-check) graph.
LASSO_GRAM_BUCKETS = {
    "tiny": (64,),
    "adlike": (128, 512),
    "wide": (128, 512),
}

# ------------------------------------------------------------------- mf --
MF_DATASETS = {
    "tiny": dict(n=256, m=128, k=4),
    "rec": dict(n=2048, m=1024, k=8),
}

# Row-block (W update) and column-block (H update) capacity buckets.
MF_WB_BUCKETS = {
    "tiny": (64, 256),
    "rec": (256, 1024, 2048),
}
MF_HB_BUCKETS = {
    "tiny": (64, 128),
    "rec": (256, 1024),
}


def manifest_entries():
    """Yield (name, kind, params) for every artifact to build."""
    for ds, dims in LASSO_DATASETS.items():
        n, j = dims["n"], dims["j"]
        for p in LASSO_P_BUCKETS[ds]:
            yield (
                f"lasso_update_{ds}_p{p}",
                "lasso_update",
                dict(dataset=ds, n=n, j=j, p=p),
            )
        for c in LASSO_GRAM_BUCKETS[ds]:
            yield (
                f"lasso_gram_{ds}_c{c}",
                "lasso_gram",
                dict(dataset=ds, n=n, j=j, c=c),
            )
        yield (f"lasso_obj_{ds}", "lasso_obj", dict(dataset=ds, n=n, j=j))

    for ds, dims in MF_DATASETS.items():
        n, m, k = dims["n"], dims["m"], dims["k"]
        for b in MF_WB_BUCKETS[ds]:
            yield (
                f"mf_update_w_{ds}_b{b}",
                "mf_update_w",
                dict(dataset=ds, n=n, m=m, k=k, b=b),
            )
        for b in MF_HB_BUCKETS[ds]:
            yield (
                f"mf_update_h_{ds}_b{b}",
                "mf_update_h",
                dict(dataset=ds, n=n, m=m, k=k, b=b),
            )
        yield (f"mf_obj_{ds}", "mf_obj", dict(dataset=ds, n=n, m=m, k=k))
