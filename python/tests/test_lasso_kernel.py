"""L1 correctness: lasso_cd Pallas kernel vs the pure-jnp oracle.

Tolerances are f32 accumulation-order bounds: the kernel reduces over
row tiles while the oracle does one dot, so results differ by O(1e-5)
on unit-scale inputs.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import lasso_cd, ref
from .conftest import assert_close

ROW_TILE = lasso_cd.ROW_TILE


def make_case(rng, n, p, mask_prob=0.8, lam=0.1):
    x = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    # unit-norm columns, as the scheduler guarantees
    x = x / jnp.linalg.norm(x, axis=0, keepdims=True)
    r = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    beta = jnp.asarray(rng.normal(size=(1, p)), jnp.float32)
    mask = jnp.asarray((rng.random((1, p)) < mask_prob).astype(np.float32))
    lam = jnp.asarray([[lam]], jnp.float32)
    return x, r, beta, mask, lam


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    p=st.integers(min_value=1, max_value=48),
    lam=st.floats(min_value=0.0, max_value=2.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_cd_update_matches_ref(tiles, p, lam, seed):
    rng = np.random.default_rng(seed)
    args = make_case(rng, tiles * ROW_TILE, p, lam=lam)
    got = lasso_cd.cd_update(*args)
    want = ref.cd_update_ref(*args)
    for g, w, name in zip(got, want, ["beta_new", "delta", "r_new"]):
        assert_close(g, w, msg=name)


def test_masked_lanes_are_frozen(rng):
    x, r, beta, _, lam = make_case(rng, 2 * ROW_TILE, 8)
    mask = jnp.zeros((1, 8), jnp.float32).at[0, :4].set(1.0)
    beta_new, delta, r_new = lasso_cd.cd_update(x, r, beta, mask, lam)
    # masked lanes keep old beta exactly, delta exactly zero
    np.testing.assert_array_equal(np.asarray(beta_new)[0, 4:], np.asarray(beta)[0, 4:])
    np.testing.assert_array_equal(np.asarray(delta)[0, 4:], 0.0)


def test_soft_threshold_zeroes_small_coefficients(rng):
    x, r, _, _, _ = make_case(rng, ROW_TILE, 4)
    beta = jnp.zeros((1, 4), jnp.float32)
    mask = jnp.ones((1, 4), jnp.float32)
    lam = jnp.asarray([[1e6]], jnp.float32)  # huge penalty
    beta_new, delta, r_new = lasso_cd.cd_update(x, r, beta, mask, lam)
    np.testing.assert_array_equal(np.asarray(beta_new), 0.0)
    assert_close(r_new, r)  # no delta -> residual unchanged


def test_residual_downdate_is_exact_rank_p(rng):
    x, r, beta, mask, lam = make_case(rng, 3 * ROW_TILE, 16)
    beta_new, delta, r_new = lasso_cd.cd_update(x, r, beta, mask, lam)
    want = np.asarray(r) - np.asarray(x) @ np.asarray(delta).T
    assert_close(r_new, want)


def test_duplicate_free_idempotence(rng):
    # applying a zero-delta update leaves everything unchanged
    x, r, beta, mask, lam = make_case(rng, ROW_TILE, 8)
    beta1, _, r1 = lasso_cd.cd_update(x, r, beta, mask, lam)
    beta2, delta2, r2 = lasso_cd.cd_update(x, r1, beta1, mask, lam)
    # second update from the fixed point of the first: beta already
    # thresholded against r1... not exactly a fixed point, but delta2
    # must be smaller than the first step on average (contraction).
    d1 = np.abs(np.asarray(beta1) - np.asarray(beta))
    d2 = np.abs(np.asarray(delta2))
    assert d2.mean() <= d1.mean() + 1e-6
