"""L1 correctness: mf_ccd rank-1 Pallas kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import mf_ccd, ref
from .conftest import assert_close


def make_case(rng, b, l, density=0.15, lam=0.05):
    rt = jnp.asarray(rng.normal(size=(b, l)), jnp.float32)
    mask = jnp.asarray((rng.random((b, l)) < density).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, l)), jnp.float32)
    lam = jnp.asarray([[lam]], jnp.float32)
    return rt, mask, v, lam


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=96),
    tiles=st.integers(min_value=1, max_value=4),
    density=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_rank1_update_matches_ref(b, tiles, density, seed):
    rng = np.random.default_rng(seed)
    args = make_case(rng, b, tiles * 128, density=density)
    assert_close(mf_ccd.rank1_update(*args), ref.rank1_update_ref(*args))


def test_256_tile_path(rng):
    # l divisible by 256 exercises the wide-tile branch
    args = make_case(rng, 32, 512)
    assert_close(mf_ccd.rank1_update(*args), ref.rank1_update_ref(*args))


def test_empty_rows_give_zero(rng):
    rt, _, v, lam = make_case(rng, 8, 128)
    mask = jnp.zeros((8, 128), jnp.float32)
    out = mf_ccd.rank1_update(rt, mask, v, lam)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_fully_observed_is_least_squares(rng):
    # with full mask and lam=0, out_i = <rt_i, v> / ||v||^2 (the exact
    # rank-1 LS solution per row)
    rt, _, v, _ = make_case(rng, 16, 128)
    mask = jnp.ones((16, 128), jnp.float32)
    lam = jnp.asarray([[0.0]], jnp.float32)
    out = mf_ccd.rank1_update(rt, mask, v, lam)
    want = (np.asarray(rt) @ np.asarray(v).T) / (np.asarray(v) @ np.asarray(v).T)
    assert_close(out, want)


def test_lambda_shrinks_towards_zero(rng):
    rt, mask, v, _ = make_case(rng, 16, 128, density=0.5)
    small = mf_ccd.rank1_update(rt, mask, v, jnp.asarray([[1e-4]], jnp.float32))
    big = mf_ccd.rank1_update(rt, mask, v, jnp.asarray([[1e4]], jnp.float32))
    assert np.abs(np.asarray(big)).sum() < np.abs(np.asarray(small)).sum()
