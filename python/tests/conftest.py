"""Shared pytest fixtures/utilities for the kernel + model tests."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20130123)


def assert_close(a, b, rtol=1e-4, atol=1e-4, msg=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol, err_msg=msg)
