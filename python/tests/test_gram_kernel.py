"""L1 correctness: gram Pallas kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, ref
from .conftest import assert_close


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    c=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gram_matches_ref(tiles, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(tiles * 128, c)), jnp.float32)
    assert_close(gram.gram(x), ref.gram_ref(x), rtol=2e-4, atol=2e-4)


def test_gram_is_symmetric_psd(rng):
    x = jnp.asarray(rng.normal(size=(256, 32)), jnp.float32)
    g = np.asarray(gram.gram(x))
    assert_close(g, g.T)
    eig = np.linalg.eigvalsh(g.astype(np.float64))
    assert eig.min() > -1e-3


def test_unit_columns_give_unit_diagonal(rng):
    x = rng.normal(size=(128, 16)).astype(np.float32)
    x = x / np.linalg.norm(x, axis=0, keepdims=True)
    g = np.asarray(gram.gram(jnp.asarray(x)))
    assert_close(np.diag(g), np.ones(16), rtol=1e-4, atol=1e-4)
