"""AOT pipeline: every manifest entry lowers, the HLO text parses
structurally, and the manifest stays consistent with shapes.py."""

import json
import os

import pytest

from compile import aot, shapes


def test_manifest_entries_cover_all_kinds():
    kinds = {kind for _, kind, _ in shapes.manifest_entries()}
    assert kinds == {
        "lasso_update",
        "lasso_gram",
        "lasso_obj",
        "mf_update_w",
        "mf_update_h",
        "mf_obj",
    }


def test_example_args_shapes_are_consistent():
    for name, kind, params in shapes.manifest_entries():
        args = aot.example_args(kind, params)
        assert all(hasattr(a, "shape") for a in args), name
        if kind == "lasso_update":
            n, j, p = params["n"], params["j"], params["p"]
            assert args[0].shape == (n, j)
            assert args[3].shape == (p,)


def test_row_dims_are_tile_aligned():
    for ds, dims in shapes.LASSO_DATASETS.items():
        assert dims["n"] % shapes.ROW_TILE == 0, ds


def test_mf_reduced_dims_are_tile_aligned():
    for ds, dims in shapes.MF_DATASETS.items():
        assert dims["m"] % 128 == 0, ds
        assert dims["n"] % 128 == 0, ds


@pytest.mark.slow
def test_tiny_family_lowers_and_manifest_is_valid(tmp_path):
    aot.build(str(tmp_path), only="tiny")
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    names = {e["name"] for e in manifest["artifacts"]}
    assert "lasso_update_tiny_p16" in names
    for e in manifest["artifacts"]:
        path = tmp_path / e["file"]
        assert path.exists()
        head = path.read_text()[:200]
        assert head.startswith("HloModule"), e["name"]


@pytest.mark.slow
def test_partial_rebuild_merges_manifest(tmp_path):
    aot.build(str(tmp_path), only="lasso_obj_tiny")
    aot.build(str(tmp_path), only="mf_obj_tiny")
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    names = {e["name"] for e in manifest["artifacts"]}
    assert {"lasso_obj_tiny", "mf_obj_tiny"} <= names
