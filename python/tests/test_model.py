"""L2 correctness: the full AOT graphs (gather + kernels + scatter) vs
numpy reference implementations of the paper's update rules."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from .conftest import assert_close

N, J, P = 128, 64, 8


@pytest.fixture()
def lasso_inputs(rng):
    x = rng.normal(size=(N, J)).astype(np.float32)
    x = x / np.linalg.norm(x, axis=0, keepdims=True)
    beta_full = np.zeros(J, np.float32)
    beta_full[::5] = rng.normal(size=len(beta_full[::5])).astype(np.float32) * 0.1
    y = (x @ beta_full + 0.05 * rng.normal(size=N)).astype(np.float32)
    r = y - x @ beta_full
    return x, y, beta_full, r


def test_lasso_update_graph(rng, lasso_inputs):
    x, y, beta_full, r = lasso_inputs
    idx = np.array([3, 17, 42, 5, 63, 0, 20, 31], np.int32)
    mask = np.ones((1, P), np.float32)
    mask[0, -2:] = 0.0  # two padded lanes
    beta_sel = beta_full[idx].reshape(1, P)
    lam = np.array([[0.01]], np.float32)
    beta_new, delta, r_new = model.lasso_update(
        jnp.asarray(x), jnp.asarray(r.reshape(N, 1)), jnp.asarray(beta_sel),
        jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(lam),
    )
    # numpy reference
    g = r @ x[:, idx] + beta_sel[0]
    want = np.sign(g) * np.maximum(np.abs(g) - 0.01, 0.0)
    want = np.where(mask[0] > 0, want, beta_sel[0])
    assert_close(beta_new[0], want)
    want_r = r - x[:, idx] @ (want - beta_sel[0])
    assert_close(r_new[:, 0], want_r)


def test_lasso_gram_graph(rng, lasso_inputs):
    x, *_ = lasso_inputs
    idx = np.array([0, 9, 33, 47], np.int32)
    (g,) = model.lasso_gram(jnp.asarray(x), jnp.asarray(idx))
    want = x[:, idx].T @ x[:, idx]
    assert_close(g, want)


def test_lasso_obj_graph(rng, lasso_inputs):
    x, y, beta_full, _ = lasso_inputs
    lam = np.array([[0.02]], np.float32)
    obj, r = model.lasso_obj(
        jnp.asarray(x), jnp.asarray(y.reshape(N, 1)),
        jnp.asarray(beta_full.reshape(J, 1)), jnp.asarray(lam),
    )
    want_obj, want_r = ref.lasso_objective_ref(
        jnp.asarray(x), jnp.asarray(y.reshape(N, 1)),
        jnp.asarray(beta_full.reshape(J, 1)), 0.02,
    )
    assert_close(obj[0, 0], want_obj)
    assert_close(r, want_r)


class TestMfGraphs:
    NN, MM, K, B = 256, 128, 4, 32

    @pytest.fixture()
    def mf_inputs(self, rng):
        a = rng.normal(size=(self.NN, self.MM)).astype(np.float32)
        mask = (rng.random((self.NN, self.MM)) < 0.2).astype(np.float32)
        w = rng.normal(size=(self.NN, self.K)).astype(np.float32) * 0.5
        h = rng.normal(size=(self.K, self.MM)).astype(np.float32) * 0.5
        return a, mask, w, h

    def test_update_w_matches_eq4(self, rng, mf_inputs):
        a, mask, w, h = mf_inputs
        t = 2
        idx = rng.choice(self.NN, size=self.B, replace=False).astype(np.int32)
        rmask = np.ones((self.B, 1), np.float32)
        rmask[-3:] = 0.0
        t1h = np.zeros((self.K, 1), np.float32)
        t1h[t] = 1.0
        lam = np.array([[0.05]], np.float32)
        w_new, dw, w_next = model.mf_update_w(
            *(jnp.asarray(v) for v in (a, mask, w, h, idx, rmask, t1h, lam))
        )
        # numpy eq. (4): w_ti = sum_j mask (r + w_t h_t) h_t / (lam + sum mask h_t^2)
        r = (a - w @ h)[idx]  # [B, M]
        mk = mask[idx]
        rt = r + np.outer(w[idx, t], h[t])
        num = (mk * rt * h[t]).sum(axis=1)
        den = 0.05 + (mk * h[t] ** 2).sum(axis=1)
        want = (num / den) * rmask[:, 0]
        assert_close(w_new[:, 0], want, rtol=2e-3, atol=2e-3)
        # scatter: w_next differs from w only in column t at idx rows
        w_next = np.asarray(w_next)
        untouched = np.ones(self.NN, bool)
        untouched[idx] = False
        assert_close(w_next[untouched], w[untouched])
        other_cols = [c for c in range(self.K) if c != t]
        assert_close(w_next[:, other_cols], w[:, other_cols])
        live = rmask[:, 0] > 0
        assert_close(w_next[idx[live], t], want[live], rtol=2e-3, atol=2e-3)
        # padded rows keep old w_t
        assert_close(w_next[idx[~live], t], w[idx[~live], t])

    def test_update_h_matches_eq5(self, rng, mf_inputs):
        a, mask, w, h = mf_inputs
        t = 1
        idx = rng.choice(self.MM, size=self.B, replace=False).astype(np.int32)
        cmask = np.ones((self.B, 1), np.float32)
        t1h = np.zeros((self.K, 1), np.float32)
        t1h[t] = 1.0
        lam = np.array([[0.05]], np.float32)
        h_new, dh, h_next = model.mf_update_h(
            *(jnp.asarray(v) for v in (a, mask, w, h, idx, cmask, t1h, lam))
        )
        r = (a - w @ h)[:, idx]  # [N, B]
        mk = mask[:, idx]
        rt = r + np.outer(w[:, t], h[t, idx])
        num = (mk * rt * w[:, [t]]).sum(axis=0)
        den = 0.05 + (mk * w[:, [t]] ** 2).sum(axis=0)
        want = num / den
        assert_close(h_new[:, 0], want, rtol=2e-3, atol=2e-3)
        h_next = np.asarray(h_next)
        assert_close(h_next[t, idx], want, rtol=2e-3, atol=2e-3)

    def test_obj_matches_eq3(self, rng, mf_inputs):
        a, mask, w, h = mf_inputs
        lam = np.array([[0.05]], np.float32)
        (obj,) = model.mf_obj(*(jnp.asarray(v) for v in (a, mask, w, h, lam)))
        want = ref.mf_objective_ref(
            jnp.asarray(a), jnp.asarray(mask), jnp.asarray(w), jnp.asarray(h), 0.05
        )
        assert_close(obj[0, 0], want, rtol=1e-4)
