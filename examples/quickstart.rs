//! Quickstart: schedule a tiny parallel Lasso with STRADS and watch the
//! objective fall.
//!
//! ```bash
//! make artifacts            # once; enables the PJRT hot path
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the AOT artifacts when available, falling back to the native
//! backend with a note otherwise.

use std::rc::Rc;
use strads::config::{EngineConfig, RunConfig};
use strads::data::lasso_synth::{generate, LassoSynthSpec};
use strads::engine::run_rounds;
use strads::lasso::{ArtifactLasso, NativeLasso};
use strads::metrics::Trace;
use strads::problem::ModelProblem;
use strads::runtime::{default_artifacts_dir, ArtifactStore, LassoExes};
use strads::schedulers::DynamicScheduler;
use strads::sim::{CostModel, VirtualCluster};

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig {
        workers: 8,
        lambda: 1e-3,
        engine: EngineConfig { max_rounds: 400, record_every: 25, ..Default::default() },
        ..Default::default()
    };
    cfg.sap.shards = 2;
    cfg.sap.rho = 0.25; // above the N=128 correlation noise floor

    println!("generating tiny correlated-design lasso problem ...");
    let data = generate(&LassoSynthSpec::tiny(), cfg.engine.seed);
    println!("  N = {}, J = {}", data.n(), data.j());

    let mut cluster = VirtualCluster::new(cfg.workers, cfg.sap.shards, CostModel::new(&cfg.cost));
    let mut trace = Trace::new("dynamic", "tiny", cfg.workers);

    match ArtifactStore::open(&default_artifacts_dir()) {
        Ok(store) => {
            println!("executing through AOT artifacts (PJRT hot path)");
            let exes = LassoExes::new(Rc::new(store), "tiny", &data.x.to_row_major(), &data.y)?;
            let mut problem = ArtifactLasso::new(exes, &data.y, cfg.lambda);
            let mut sched = DynamicScheduler::new(problem.num_vars(), &cfg.sap, cfg.engine.seed);
            run_rounds(&mut problem, &mut sched, &mut cluster, &cfg.engine, &mut trace);
            print_trace(&trace, problem.active_vars());
        }
        Err(e) => {
            println!("artifacts unavailable ({e}); using the native backend");
            let mut problem = NativeLasso::new(&data, cfg.lambda);
            let mut sched = DynamicScheduler::new(problem.num_vars(), &cfg.sap, cfg.engine.seed);
            run_rounds(&mut problem, &mut sched, &mut cluster, &cfg.engine, &mut trace);
            print_trace(&trace, problem.active_vars());
        }
    }
    Ok(())
}

fn print_trace(trace: &Trace, active: usize) {
    println!("\n  round    vtime(s)    objective     active");
    for p in &trace.points {
        println!("  {:>5}   {:>8.3}   {:>11.5e}   {:>6}", p.round, p.vtime, p.objective, p.active_vars);
    }
    println!("\nfinal objective {:.6e} with {} active coefficients", trace.final_objective(), active);
}
