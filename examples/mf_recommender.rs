//! Recommender-system MF through the artifact hot path: factorize a
//! power-law ratings matrix with CCD, comparing STRADS load-balanced
//! blocks against naive uniform partitioning (the Fig 5 comparison, on
//! the Yahoo-like skew where it matters most).
//!
//! ```bash
//! make artifacts && cargo run --release --example mf_recommender [iters]
//! ```

use std::rc::Rc;
use strads::config::{CostModelConfig, EngineConfig};
use strads::data::mf_powerlaw::{generate, gini, MfSynthSpec};
use strads::metrics::Trace;
use strads::mf::{run_mf, ArtifactMf, MfBackend, MfPartition};
use strads::runtime::{default_artifacts_dir, ArtifactStore, MfExes};

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("iters"))
        .unwrap_or(5);
    let workers = 16;

    // tiny shapes keep the dense device form small; the skew is what
    // matters, so crank the exponents to Yahoo-like levels
    let spec = MfSynthSpec {
        user_exponent: 1.1,
        item_exponent: 1.4,
        nnz: 8_000,
        ..MfSynthSpec::tiny()
    };
    let data = generate(&spec, 2013);
    println!(
        "ratings: {} users x {} items, {} observed (col-nnz gini {:.2})",
        data.a.nrows(),
        data.a.ncols(),
        data.a.nnz(),
        gini(&data.a.col_nnz())
    );

    let store = Rc::new(ArtifactStore::open(&default_artifacts_dir())?);
    let (a_dense, mask) = data.a.to_dense_row_major();
    let ecfg = EngineConfig { max_rounds: iters, record_every: 1, ..Default::default() };
    // tiny blocks: drop the dispatch overhead so compute (the straggler
    // effect under test) dominates the round time, as it does at the
    // fig5 scale.
    let cost = CostModelConfig { round_overhead_sec: 1e-5, ..Default::default() };

    let csv = std::path::Path::new("results/mf_recommender.csv");
    let _ = std::fs::remove_file(csv);
    let mut vtimes = Vec::new();
    for part in [MfPartition::Balanced, MfPartition::Uniform] {
        let exes = MfExes::new(Rc::clone(&store), "tiny", &a_dense, &mask)?;
        let mut backend = ArtifactMf::new(exes, &data.a, 0.05, 7);
        let mut trace = Trace::new(part.name(), "powerlaw", workers);
        let wall = std::time::Instant::now();
        run_mf(&mut backend, part, workers, &ecfg, &cost, &mut trace);
        let rmse = (backend.objective() / data.a.nnz() as f64).sqrt();
        println!(
            "  {:<9} obj {:.5e} (rmse~{:.4})  vtime {:>8.3}s  straggler x{:.2}  (wall {:.1}s)",
            part.name(),
            trace.final_objective(),
            rmse,
            trace.final_vtime(),
            trace.points.last().map(|p| p.imbalance).unwrap_or(1.0),
            wall.elapsed().as_secs_f64()
        );
        trace.append_csv(csv)?;
        vtimes.push(trace.final_vtime());
    }
    println!(
        "\nload balancing finished the same updates {:.2}x faster in cluster time",
        vtimes[1] / vtimes[0]
    );
    println!("wrote results/mf_recommender.csv");
    Ok(())
}
