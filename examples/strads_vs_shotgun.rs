//! Fig 1 driver: STRADS (dynamic blocks) vs Shotgun (no structure) on
//! the AD-regime Lasso, λ = 5e-4 — the paper's opening figure.
//!
//! ```bash
//! cargo run --release --example strads_vs_shotgun [rounds]
//! ```
//!
//! Writes `results/fig1_lasso.csv`; plot objective vs vtime per
//! scheduler to recreate Figure 1.

use strads::config::{EngineConfig, RunConfig};
use strads::experiments;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("rounds"))
        .unwrap_or(1500);
    let cfg = RunConfig {
        workers: 32,
        lambda: 5e-4,
        engine: EngineConfig {
            max_rounds: rounds,
            record_every: 10,
            objective_every: 100,
            ..Default::default()
        },
        ..Default::default()
    };
    let csv = std::path::Path::new("results/fig1_lasso.csv");
    let _ = std::fs::remove_file(csv);
    let traces = experiments::fig1(&cfg, Some(csv));

    // The paper's Fig 1 story: STRADS escapes the slow trajectory and
    // lands at a better objective.
    let dynamic = &traces[0];
    let random = &traces[1];
    println!("\nfinal objective: STRADS {:.6e} vs Shotgun {:.6e}", dynamic.final_objective(), random.final_objective());
    if let Some(t) = dynamic.time_to_reach(random.final_objective()) {
        println!(
            "STRADS reached Shotgun's final quality at vtime {t:.2}s (Shotgun took {:.2}s)",
            random.final_vtime()
        );
    }
    println!("wrote {}", csv.display());
    Ok(())
}
