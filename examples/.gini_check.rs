fn main() {
    for (name, ue, ie) in [("yahoo-1.1/1.4", 1.1, 1.4), ("yahoo-1.2/1.8", 1.2, 1.8), ("yahoo-1.3/2.2", 1.3, 2.2)] {
        let spec = strads::data::mf_powerlaw::MfSynthSpec {
            user_exponent: ue, item_exponent: ie,
            ..strads::data::mf_powerlaw::MfSynthSpec::yahoo_like()
        };
        let d = strads::data::mf_powerlaw::generate(&spec, 42);
        let cg = strads::data::mf_powerlaw::gini(&d.a.col_nnz());
        let rw: Vec<u64> = (0..d.a.nrows()).map(|i| d.a.row_nnz(i) as u64).collect();
        let cw: Vec<u64> = d.a.col_nnz().iter().map(|&c| c as u64).collect();
        for p in [4usize, 16] {
            let bu = strads::coordinator::balance::partition_uniform(&cw, p);
            let bb = strads::coordinator::balance::partition_balanced(&cw, p);
            let _ = &rw;
            println!("{name} nnz={} col-gini={cg:.2} P={p}: uniform imb {:.2}, balanced imb {:.2}",
                d.a.nnz(),
                strads::coordinator::balance::imbalance(&bu),
                strads::coordinator::balance::imbalance(&bb));
        }
    }
}
