//! End-to-end driver — the repository's headline validation run.
//!
//! A genomics-regime sparse regression (the paper's Alzheimer's-disease
//! workload, §5.1): N = 463 samples, J = 4096 correlated SNP-like
//! covariates, λ = 5e-4, exactly the paper's setting. All three
//! schedulers run the identical problem with the full production stack:
//! the batched CD update, the dependency-check Gram, and the objective
//! all execute as AOT-compiled XLA artifacts (Pallas kernels inside)
//! through PJRT from the rust coordinator — python is not running.
//!
//! Outputs: objective-vs-virtual-time curves for every scheduler to
//! `results/lasso_genomics.csv` and a headline summary table. Recorded
//! in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example lasso_genomics
//! ```

use std::rc::Rc;
use strads::config::{EngineConfig, RunConfig};
use strads::data::lasso_synth::{generate, LassoSynthSpec};
use strads::engine::run_rounds;
use strads::experiments::SchedKind;
use strads::lasso::ArtifactLasso;
use strads::metrics::Trace;
use strads::problem::ModelProblem;
use strads::runtime::{default_artifacts_dir, ArtifactStore, LassoExes};
use strads::sim::{CostModel, VirtualCluster};

fn main() -> anyhow::Result<()> {
    let workers = 64;
    let rounds: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("rounds"))
        .unwrap_or(400);

    let mut cfg = RunConfig {
        workers,
        lambda: 5e-4, // the paper's lambda for the AD dataset
        engine: EngineConfig {
            max_rounds: rounds,
            record_every: 10,
            objective_every: 50,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sap.rho = 0.1; // the paper's rho
    cfg.sap.shards = 4;

    println!("generating AD-regime dataset (463 live samples, correlated blocks) ...");
    let data = generate(&LassoSynthSpec::adlike(), cfg.engine.seed);
    println!("  N = {} (padded), J = {}, lambda = {}", data.n(), data.j(), cfg.lambda);

    let store = Rc::new(ArtifactStore::open(&default_artifacts_dir())?);
    println!(
        "artifact store: {} artifacts; executing the full hot path through PJRT",
        store.artifacts().len()
    );

    let csv = std::path::Path::new("results/lasso_genomics.csv");
    let _ = std::fs::remove_file(csv);
    let mut summaries = Vec::new();
    for kind in [SchedKind::Dynamic, SchedKind::Static, SchedKind::Random] {
        let wall = std::time::Instant::now();
        let exes =
            LassoExes::new(Rc::clone(&store), "adlike", &data.x.to_row_major(), &data.y)?;
        let mut problem = ArtifactLasso::new(exes, &data.y, cfg.lambda);
        let mut sched = kind.build(problem.num_vars(), &cfg.sap, cfg.engine.seed);
        let mut cluster =
            VirtualCluster::new(cfg.workers, cfg.sap.shards, CostModel::new(&cfg.cost));
        let mut trace = Trace::new(kind.name(), "adlike", cfg.workers);
        run_rounds(&mut problem, sched.as_mut(), &mut cluster, &cfg.engine, &mut trace);
        trace.append_csv(csv)?;
        println!(
            "  {:<8} final obj {:.6e}  active {:>4}  vtime {:>8.2}s  (wall {:>6.1}s)",
            kind.name(),
            trace.final_objective(),
            problem.active_vars(),
            trace.final_vtime(),
            wall.elapsed().as_secs_f64()
        );
        summaries.push((kind.name(), trace));
    }

    // Headline: time for each scheduler to reach the random scheduler's
    // final quality (the paper's "converges much more quickly" claim).
    let threshold = summaries
        .iter()
        .find(|(n, _)| *n == "random")
        .map(|(_, t)| t.final_objective())
        .unwrap();
    println!("\nheadline: virtual time to reach random's final objective ({threshold:.4e})");
    for (name, t) in &summaries {
        match t.time_to_reach(threshold * 1.0001) {
            Some(v) => println!("  {name:<8} {v:>8.2}s"),
            None => println!("  {name:<8} never"),
        }
    }
    println!("\nwrote results/lasso_genomics.csv");
    Ok(())
}
